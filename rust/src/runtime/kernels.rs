//! The unified reduce-side compute kernel layer.
//!
//! Every block algebra's local multiply bottoms out here:
//!
//! * [`gemm_acc`] — register-tiled f32 GEMM (`C += A·B`): MR×NR
//!   register accumulator blocks over packed B column panels, with the
//!   k-loop tiled so each packed panel stays in cache across all row
//!   blocks. This is the arithmetic hot path behind
//!   [`NativeMultiply`](super::native::NativeMultiply).
//! * [`gemm_acc_sr`] — generic tiled semiring GEMM (`C ⊕= A ⊗ B`) in
//!   the same `i-k-j` contiguous-row layout; `(min,+)` and `(∨,∧)`
//!   products (APSP / transitive-closure reductions) run through it
//!   instead of the naive `get()`-based triple loop.
//! * [`gemm_acc_ikj`] — the pre-overhaul vectorised scalar row loop,
//!   kept as the perf baseline the tiled kernel is benchmarked against
//!   (`m3 bench-kernels`).
//!
//! The naive triple loops in [`crate::matrix::DenseMatrix`]
//! (`matmul_naive` / `matmul_naive_sr`) remain the correctness oracles;
//! the property tests below pin each kernel against them bit-for-bit on
//! integer-valued inputs at shapes that straddle every tile boundary.
//!
//! The sparse counterpart (epoch-marked Gustavson SpGEMM, merged-row
//! CSR add/sum) lives with the CSR representation in
//! [`crate::matrix::sparse`].

use crate::matrix::semiring::Semiring;

/// Rows per register block: MR accumulator rows are held in registers
/// across the entire k-tile.
pub const MR: usize = 4;

/// Columns per register block / packed-panel width: NR accumulator
/// lanes per row, sized for two 4-wide SIMD registers.
pub const NR: usize = 8;

/// k-tile length: the packed `KB × NR` B panel (8 KiB at f32) stays in
/// L1 while every MR-row block of A streams over it.
pub const KB: usize = 256;

/// Pack the `[k0, k1) × [j0, j0+NR)` tile of row-major `b` into
/// `packb` so the microkernel reads it as contiguous NR-wide rows.
#[inline]
fn pack_b_panel(b: &[f32], n: usize, k0: usize, k1: usize, j0: usize, packb: &mut [f32]) {
    for (kk, krow) in (k0..k1).enumerate() {
        let src = &b[krow * n + j0..krow * n + j0 + NR];
        packb[kk * NR..kk * NR + NR].copy_from_slice(src);
    }
}

/// MR×NR microkernel: accumulate the k-tile product into the register
/// block, then flush it into `c_tile`. `a_tile`/`c_tile` are the full
/// row-major slices offset to the block's top-left corner (strides
/// `lda`/`ldc`). The `MR`/`NR` loops have constant bounds, so they
/// unroll into straight-line FMAs.
#[inline]
fn microkernel(
    kt: usize,
    a_tile: &[f32],
    lda: usize,
    packb: &[f32],
    c_tile: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..kt {
        let bp = &packb[kk * NR..kk * NR + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a_tile[r * lda + kk];
            for jj in 0..NR {
                accr[jj] += av * bp[jj];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c_tile[r * ldc..r * ldc + NR];
        for jj in 0..NR {
            crow[jj] += accr[jj];
        }
    }
}

/// Register-tiled `c += a·b` on raw row-major slices.
///
/// `a`: `m×k`, `b`: `k×n`, `c`: `m×n`. Full `MR × NR` tiles go through
/// the packed microkernel; row and column remainders fall back to the
/// scalar row loop so every shape is supported.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_main = n - n % NR; // columns covered by full packed panels
    let m_main = m - m % MR; // rows covered by full register blocks
    let mut packb = [0.0f32; KB * NR];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        let kt = k1 - k0;
        let mut j0 = 0;
        while j0 < n_main {
            // One pack per (k-tile, panel) amortised over all m/MR
            // register blocks.
            pack_b_panel(b, n, k0, k1, j0, &mut packb);
            let mut i0 = 0;
            while i0 < m_main {
                microkernel(kt, &a[i0 * k + k0..], k, &packb, &mut c[i0 * n + j0..], n);
                i0 += MR;
            }
            // Row remainder against the packed panel.
            for i in m_main..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j0 + NR];
                for kk in 0..kt {
                    let av = arow[k0 + kk];
                    let bp = &packb[kk * NR..kk * NR + NR];
                    for jj in 0..NR {
                        crow[jj] += av * bp[jj];
                    }
                }
            }
            j0 += NR;
        }
        // Column remainder (n % NR) for all rows: scalar row loop. No
        // zero-skip here — the microkernel path has none, so every
        // output column sees identical `c += a*b` IEEE semantics.
        if n_main < n {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in n_main..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
        k0 = k1;
    }
}

/// The pre-overhaul kernel: scalar `i-k-j` row loop with k-tiling, no
/// register blocking or packing. Kept verbatim — including its
/// original `KB = 64` k-tile — as the perf baseline for
/// `m3 bench-kernels`, so `speedup_vs_ikj` is a true before/after
/// comparison; [`gemm_acc`] must beat it.
pub fn gemm_acc_ikj(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    const KB_IKJ: usize = 64; // the shipped pre-overhaul k-tile
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB_IKJ).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled semiring GEMM `c ⊕= a ⊗ b` on raw row-major slices.
///
/// Same `i-k-j` contiguous-row layout and k-tiling as [`gemm_acc`]: the
/// inner loop walks rows of `b` and `c` as slices, so `⊕`/`⊗` pairs
/// that lower to machine ops (`min`+`add` for the tropical semiring)
/// auto-vectorise — unlike the `get()`-based naive triple loop.
///
/// `c` must be initialised by the caller (to `S::zero()` for a fresh
/// product). Entries of `a` equal to `S::zero()` are skipped: `zero`
/// is the ⊗-annihilator and the ⊕-identity in every semiring, so the
/// skip is exact.
pub fn gemm_acc_sr<S: Semiring>(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if S::is_zero(av) {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv = S::add(*cv, S::mul(av, bv));
                }
            }
        }
        k0 = k1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::matrix::semiring::{Arithmetic, BoolOrAnd, MinPlus};
    use crate::matrix::DenseMatrix;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    /// Run the f32 kernel on matrices and return the result.
    fn run_gemm(a: &DenseMatrix, b: &DenseMatrix, c: &DenseMatrix) -> DenseMatrix {
        let mut out = c.clone();
        gemm_acc(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    fn run_gemm_sr<S: Semiring>(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let mut out = DenseMatrix::filled(a.rows(), b.cols(), S::zero());
        gemm_acc_sr::<S>(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    #[test]
    fn tiled_gemm_matches_naive_at_tile_boundaries() {
        // Shapes straddling MR (4), NR (8), and KB (256): one below,
        // exact, one above each boundary.
        let mut rng = Xoshiro256ss::new(1);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 9),
            (7, 255, 6),
            (8, 256, 16),
            (9, 257, 17),
            (12, 300, 23),
        ] {
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            assert_eq!(run_gemm(&a, &b, &c), want, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn prop_tiled_gemm_matches_naive() {
        run_prop("register-tiled gemm == naive", 30, |case| {
            // Cross every tile size: m over MR, n over NR, k over KB.
            let m = 1 + case.rng.next_usize(2 * MR + 3);
            let n = 1 + case.rng.next_usize(3 * NR + 3);
            let k = 1 + case.rng.next_usize(KB + 40);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            if run_gemm(&a, &b, &c) != want {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tiled_gemm_matches_ikj_baseline() {
        run_prop("register-tiled gemm == ikj baseline", 15, |case| {
            let m = 1 + case.rng.next_usize(12);
            let n = 1 + case.rng.next_usize(20);
            let k = 1 + case.rng.next_usize(64);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let tiled = run_gemm(&a, &b, &c);
            let mut base = c.clone();
            gemm_acc_ikj(m, k, n, a.as_slice(), b.as_slice(), base.as_mut_slice());
            if tiled != base {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn semiring_gemm_matches_naive_all_semirings() {
        fn check<S: Semiring>(rng: &mut Xoshiro256ss) {
            for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (8, 9, 8), (5, 257, 11)] {
                let a = gen::dense_int(m, k, rng);
                let b = gen::dense_int(k, n, rng);
                let want = a.matmul_naive_sr::<S>(&b);
                assert_eq!(
                    run_gemm_sr::<S>(&a, &b),
                    want,
                    "{} shape {m}x{k}x{n}",
                    S::name()
                );
            }
        }
        fn dist(rows: usize, cols: usize, rng: &mut Xoshiro256ss) -> DenseMatrix {
            DenseMatrix::from_fn(rows, cols, |_, _| {
                if rng.bernoulli(0.4) {
                    rng.range_u64(0, 9) as f32
                } else {
                    f32::INFINITY
                }
            })
        }
        let mut rng = Xoshiro256ss::new(2);
        check::<Arithmetic>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
        // MinPlus over distance-like matrices (∞ = no edge), so the
        // ⊕-identity actually occurs in the data.
        for &(m, k, n) in &[(3, 3, 3), (6, 9, 7), (4, 258, 5)] {
            let a = dist(m, k, &mut rng);
            let b = dist(k, n, &mut rng);
            let want = a.matmul_naive_sr::<MinPlus>(&b);
            assert_eq!(run_gemm_sr::<MinPlus>(&a, &b), want, "minplus {m}x{k}x{n}");
        }
    }

    #[test]
    fn prop_semiring_gemm_matches_naive() {
        run_prop("tiled semiring gemm == naive", 20, |case| {
            let m = 1 + case.rng.next_usize(10);
            let k = 1 + case.rng.next_usize(40);
            let n = 1 + case.rng.next_usize(14);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            if run_gemm_sr::<Arithmetic>(&a, &b) != a.matmul_naive_sr::<Arithmetic>(&b) {
                return Err(format!("arithmetic mismatch at {m}x{k}x{n}"));
            }
            // Boolean view of the same supports.
            let ab = DenseMatrix::from_fn(m, k, |i, j| if a.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            let bb = DenseMatrix::from_fn(k, n, |i, j| if b.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            if run_gemm_sr::<BoolOrAnd>(&ab, &bb) != ab.matmul_naive_sr::<BoolOrAnd>(&bb) {
                return Err(format!("boolean mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn empty_shapes_are_noops() {
        gemm_acc(0, 3, 3, &[], &[0.0; 9], &mut []);
        let mut c1 = [7.0f32; 4];
        gemm_acc(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        gemm_acc_sr::<Arithmetic>(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
    }
}
