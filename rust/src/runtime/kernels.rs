//! The unified reduce-side compute kernel layer.
//!
//! Every block algebra's local multiply bottoms out here:
//!
//! * [`gemm_acc`] — register-tiled f32 GEMM (`C += A·B`): MR×NR
//!   register accumulator blocks over packed B column panels, with the
//!   k-loop tiled so each packed panel stays in cache across all row
//!   blocks. The MR/NR shape is **autotuned** once per process: a small
//!   fixed candidate set ([`TILE_CANDIDATES`]) is probed at pool
//!   startup ([`ensure_tuned`], triggered by the executor's first
//!   spawn) and the winner is cached — SIMD-width differences between
//!   hosts pick different register blocks without recompiling.
//! * [`gemm_acc_par`] — the same kernel with **intra-task tile
//!   parallelism**: when the calling thread is a pool task and the
//!   product volume crosses [`PAR_MIN_VOLUME`], the C rows are split
//!   into MR-aligned row panels published as stealable subtasks
//!   ([`crate::mapreduce::executor::run_subtasks`]). Panels write
//!   disjoint C row ranges, so no locking — and because every panel
//!   boundary is a multiple of the register-block height MR, each row
//!   sees exactly the accumulation order of the sequential kernel: the
//!   parallel result is **bit-identical** to [`gemm_acc`].
//! * [`gemm_acc_sr`] / [`gemm_acc_sr_par`] — generic tiled semiring
//!   GEMM (`C ⊕= A ⊗ B`) in the same `i-k-j` contiguous-row layout
//!   (rows are fully independent, so its row-panel split is trivially
//!   bit-identical); `(min,+)` and `(∨,∧)` products run through it.
//! * [`gemm_acc_ikj`] — the pre-overhaul vectorised scalar row loop,
//!   kept as the perf baseline the tiled kernel is benchmarked against
//!   (`m3 bench-kernels`).
//!
//! The naive triple loops in [`crate::matrix::DenseMatrix`]
//! (`matmul_naive` / `matmul_naive_sr`) remain the correctness oracles;
//! the property tests below pin each kernel against them bit-for-bit on
//! integer-valued inputs at shapes that straddle every tile boundary,
//! and the parallel entry points against their sequential twins
//! bit-for-bit on *fractional* inputs (which pins the accumulation
//! order itself).
//!
//! The sparse counterpart (epoch-marked Gustavson SpGEMM with the same
//! row-panel subtask split, merged-row CSR add/sum) lives with the CSR
//! representation in [`crate::matrix::sparse`].

use std::sync::OnceLock;
use std::time::Instant;

use crate::mapreduce::executor::{current_pool_width, run_subtasks, subtask_tiling};
use crate::matrix::semiring::Semiring;

/// Default rows per register block: MR accumulator rows are held in
/// registers across the entire k-tile.
pub const MR: usize = 4;

/// Default columns per register block / packed-panel width: NR
/// accumulator lanes per row, sized for two 4-wide SIMD registers.
pub const NR: usize = 8;

/// k-tile length: the packed `KB × NR` B panel (8 KiB at f32) stays in
/// L1 while every MR-row block of A streams over it.
pub const KB: usize = 256;

/// Widest candidate NR (sizes the packed-panel scratch buffer).
pub const NR_MAX: usize = 16;

/// The fixed candidate register-tile shapes the autotuner probes, in
/// preference order (ties go to the earlier entry). `(4, 8)` is the
/// portable default; wider NR suits 8-lane SIMD, taller MR suits
/// register-rich targets.
pub const TILE_CANDIDATES: &[(usize, usize)] = &[(4, 8), (8, 8), (4, 16), (2, 16)];

/// Product volume `m·k·n` below which a local GEMM is not worth
/// splitting into stealable tiles (a 64³ block product sits exactly on
/// the threshold).
pub const PAR_MIN_VOLUME: usize = 64 * 64 * 64;

/// Pack the `[k0, k1) × [j0, j0+nr)` tile of row-major `b` into
/// `packb` so the microkernel reads it as contiguous nr-wide rows.
#[inline]
fn pack_b_panel(
    b: &[f32],
    n: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    nr: usize,
    packb: &mut [f32],
) {
    for (kk, krow) in (k0..k1).enumerate() {
        let src = &b[krow * n + j0..krow * n + j0 + nr];
        packb[kk * nr..kk * nr + nr].copy_from_slice(src);
    }
}

/// MRV×NRV microkernel: accumulate the k-tile product into the register
/// block, then flush it into `c_tile`. `a_tile`/`c_tile` are the full
/// row-major slices offset to the block's top-left corner (strides
/// `lda`/`ldc`). The `MRV`/`NRV` loops have constant bounds, so they
/// unroll into straight-line FMAs.
#[inline]
fn microkernel<const MRV: usize, const NRV: usize>(
    kt: usize,
    a_tile: &[f32],
    lda: usize,
    packb: &[f32],
    c_tile: &mut [f32],
    ldc: usize,
) {
    let mut acc = [[0.0f32; NRV]; MRV];
    for kk in 0..kt {
        let bp = &packb[kk * NRV..kk * NRV + NRV];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a_tile[r * lda + kk];
            for jj in 0..NRV {
                accr[jj] += av * bp[jj];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c_tile[r * ldc..r * ldc + NRV];
        for jj in 0..NRV {
            crow[jj] += accr[jj];
        }
    }
}

/// Register-tiled `c += a·b` at a fixed MRV×NRV register-block shape.
/// Full tiles go through the packed microkernel; row and column
/// remainders fall back to the scalar row loop so every shape is
/// supported.
fn gemm_acc_shape<const MRV: usize, const NRV: usize>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_main = n - n % NRV; // columns covered by full packed panels
    let m_main = m - m % MRV; // rows covered by full register blocks
    let mut packb = [0.0f32; KB * NR_MAX];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        let kt = k1 - k0;
        let mut j0 = 0;
        while j0 < n_main {
            // One pack per (k-tile, panel) amortised over all m/MRV
            // register blocks.
            pack_b_panel(b, n, k0, k1, j0, NRV, &mut packb);
            let mut i0 = 0;
            while i0 < m_main {
                microkernel::<MRV, NRV>(
                    kt,
                    &a[i0 * k + k0..],
                    k,
                    &packb,
                    &mut c[i0 * n + j0..],
                    n,
                );
                i0 += MRV;
            }
            // Row remainder against the packed panel.
            for i in m_main..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j0 + NRV];
                for kk in 0..kt {
                    let av = arow[k0 + kk];
                    let bp = &packb[kk * NRV..kk * NRV + NRV];
                    for jj in 0..NRV {
                        crow[jj] += av * bp[jj];
                    }
                }
            }
            j0 += NRV;
        }
        // Column remainder (n % NRV) for all rows: scalar row loop. No
        // zero-skip here — the microkernel path has none, so every
        // output column sees identical `c += a*b` IEEE semantics.
        if n_main < n {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in n_main..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
        k0 = k1;
    }
}

/// Dispatch to the monomorphized kernel for `(mr, nr)`; unknown shapes
/// fall back to the default `(MR, NR)` instantiation.
fn gemm_acc_dispatch(
    shape: (usize, usize),
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    match shape {
        (8, 8) => gemm_acc_shape::<8, 8>(m, k, n, a, b, c),
        (4, 16) => gemm_acc_shape::<4, 16>(m, k, n, a, b, c),
        (2, 16) => gemm_acc_shape::<2, 16>(m, k, n, a, b, c),
        _ => gemm_acc_shape::<MR, NR>(m, k, n, a, b, c),
    }
}

/// One probed candidate of the MR/NR autotune.
#[derive(Debug, Clone, Copy)]
pub struct TileProbe {
    /// Register-block rows.
    pub mr: usize,
    /// Register-block columns.
    pub nr: usize,
    /// Best-of-reps seconds for the probe GEMM.
    pub secs: f64,
}

/// Result of the one-shot register-tile autotune, cached for the whole
/// process and surfaced by `m3 bench-kernels --json`.
#[derive(Debug, Clone)]
pub struct AutotuneReport {
    /// The winning `(mr, nr)` shape every `gemm_acc`-family call uses.
    pub chosen: (usize, usize),
    /// All probed candidates with their timings.
    pub candidates: Vec<TileProbe>,
}

static TUNED: OnceLock<AutotuneReport> = OnceLock::new();

fn probe_shapes() -> AutotuneReport {
    use crate::util::rng::Xoshiro256ss;
    // One full k-tile, several register blocks in each dimension —
    // large enough to rank shapes, small enough to probe in
    // milliseconds at pool startup.
    const M: usize = 64;
    const K: usize = 256;
    const N: usize = 64;
    const REPS: usize = 3;
    let mut rng = Xoshiro256ss::new(0xA070);
    let a: Vec<f32> = (0..M * K).map(|_| rng.range_u64(0, 255) as f32 / 16.0).collect();
    let b: Vec<f32> = (0..K * N).map(|_| rng.range_u64(0, 255) as f32 / 16.0).collect();
    let mut candidates = Vec::with_capacity(TILE_CANDIDATES.len());
    let mut chosen = TILE_CANDIDATES[0];
    let mut best = f64::INFINITY;
    for &(mr, nr) in TILE_CANDIDATES {
        let mut c = vec![0.0f32; M * N];
        gemm_acc_dispatch((mr, nr), M, K, N, &a, &b, &mut c); // warm-up
        let mut secs = f64::INFINITY;
        for _ in 0..REPS {
            let t = Instant::now();
            gemm_acc_dispatch((mr, nr), M, K, N, &a, &b, &mut c);
            secs = secs.min(t.elapsed().as_secs_f64());
        }
        std::hint::black_box(&c);
        candidates.push(TileProbe { mr, nr, secs });
        if secs < best {
            best = secs;
            chosen = (mr, nr);
        }
    }
    AutotuneReport { chosen, candidates }
}

/// The cached autotune result (probing on first use).
pub fn autotune_report() -> &'static AutotuneReport {
    TUNED.get_or_init(probe_shapes)
}

/// The `(mr, nr)` register-block shape in use.
pub fn tuned_shape() -> (usize, usize) {
    autotune_report().chosen
}

/// Run the autotune probe now if it has not run yet. Called at pool
/// startup ([`crate::mapreduce::executor::Pool`] spawning its workers)
/// so the probe's cost lands outside timed rounds.
pub fn ensure_tuned() {
    let _ = autotune_report();
}

/// Register-tiled `c += a·b` on raw row-major slices, at the autotuned
/// register-block shape.
///
/// `a`: `m×k`, `b`: `k×n`, `c`: `m×n`. Deterministic within a process:
/// the tuned shape is probed once and cached, so repeated runs produce
/// bit-identical results.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_acc_dispatch(tuned_shape(), m, k, n, a, b, c);
}

/// Disjoint-panel output pointer ferried into tile subtasks. Each
/// subtask manufactures a `&mut` slice over its own row range only.
struct SendPtr(*mut f32);
// SAFETY: subtasks write disjoint row panels (see `gemm_acc_par`), and
// the spawning call joins before the buffer is touched again.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// [`gemm_acc`] with intra-task tile parallelism: when the calling
/// thread is a task of a multi-worker pool and `m·k·n ≥`
/// [`PAR_MIN_VOLUME`], the C rows split into MR-aligned row panels
/// published as stealable subtasks; idle workers steal panels instead
/// of waiting out one oversized local multiply.
///
/// **Ownership rule:** each panel owns a disjoint `[i0, i1) × n` slice
/// of `c` — no two subtasks ever touch the same C element, so there is
/// no locking and no non-determinism. **Bit-identity:** every panel
/// boundary is a multiple of the register-block height `mr`, so each
/// row takes exactly the register/remainder path it takes in the
/// sequential kernel — the result is bit-for-bit equal to
/// [`gemm_acc`]'s regardless of worker count or stealing order.
pub fn gemm_acc_par(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let width = current_pool_width();
    let (mr, nr) = tuned_shape();
    if !subtask_tiling() || width <= 1 || m < 2 * mr || m * k * n < PAR_MIN_VOLUME {
        gemm_acc_dispatch((mr, nr), m, k, n, a, b, c);
        return;
    }
    // MR-aligned row panels, about two per worker so stealing can
    // rebalance mid-flight.
    let blocks = m / mr;
    let panels = blocks.min(2 * width);
    let rows_pp = blocks.div_ceil(panels) * mr;
    let num_panels = m.div_ceil(rows_pp);
    let cp = SendPtr(c.as_mut_ptr());
    run_subtasks(num_panels, |p| {
        let i0 = p * rows_pp;
        let i1 = (i0 + rows_pp).min(m);
        // SAFETY: panels cover disjoint row ranges [i0, i1); each
        // subtask writes only its own C rows, and `run_subtasks` joins
        // before `c` is read again.
        let cpan = unsafe { std::slice::from_raw_parts_mut(cp.0.add(i0 * n), (i1 - i0) * n) };
        gemm_acc_dispatch((mr, nr), i1 - i0, k, n, &a[i0 * k..i1 * k], b, cpan);
    });
}

/// The pre-overhaul kernel: scalar `i-k-j` row loop with k-tiling, no
/// register blocking or packing. Kept verbatim — including its
/// original `KB = 64` k-tile — as the perf baseline for
/// `m3 bench-kernels`, so `speedup_vs_ikj` is a true before/after
/// comparison; [`gemm_acc`] must beat it.
pub fn gemm_acc_ikj(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    const KB_IKJ: usize = 64; // the shipped pre-overhaul k-tile
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB_IKJ).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled semiring GEMM `c ⊕= a ⊗ b` on raw row-major slices.
///
/// Same `i-k-j` contiguous-row layout and k-tiling as [`gemm_acc`]: the
/// inner loop walks rows of `b` and `c` as slices, so `⊕`/`⊗` pairs
/// that lower to machine ops (`min`+`add` for the tropical semiring)
/// auto-vectorise — unlike the `get()`-based naive triple loop.
///
/// `c` must be initialised by the caller (to `S::zero()` for a fresh
/// product). Entries of `a` equal to `S::zero()` are skipped: `zero`
/// is the ⊗-annihilator and the ⊕-identity in every semiring, so the
/// skip is exact.
pub fn gemm_acc_sr<S: Semiring>(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if S::is_zero(av) {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv = S::add(*cv, S::mul(av, bv));
                }
            }
        }
        k0 = k1;
    }
}

/// [`gemm_acc_sr`] with the same stealable row-panel split as
/// [`gemm_acc_par`]. The semiring kernel's rows are fully independent
/// (no register blocking), so any row split is trivially bit-identical
/// to the sequential kernel.
pub fn gemm_acc_sr_par<S: Semiring>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let width = current_pool_width();
    if !subtask_tiling() || width <= 1 || m < 2 || m * k * n < PAR_MIN_VOLUME {
        gemm_acc_sr::<S>(m, k, n, a, b, c);
        return;
    }
    let panels = m.min(2 * width);
    let rows_pp = m.div_ceil(panels);
    let num_panels = m.div_ceil(rows_pp);
    let cp = SendPtr(c.as_mut_ptr());
    run_subtasks(num_panels, |p| {
        let i0 = p * rows_pp;
        let i1 = (i0 + rows_pp).min(m);
        // SAFETY: disjoint row panels; see `gemm_acc_par`.
        let cpan = unsafe { std::slice::from_raw_parts_mut(cp.0.add(i0 * n), (i1 - i0) * n) };
        gemm_acc_sr::<S>(i1 - i0, k, n, &a[i0 * k..i1 * k], b, cpan);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::executor::Pool;
    use crate::matrix::gen;
    use crate::matrix::semiring::{Arithmetic, BoolOrAnd, MinPlus};
    use crate::matrix::DenseMatrix;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    /// Run the f32 kernel on matrices and return the result.
    fn run_gemm(a: &DenseMatrix, b: &DenseMatrix, c: &DenseMatrix) -> DenseMatrix {
        let mut out = c.clone();
        gemm_acc(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    fn run_gemm_sr<S: Semiring>(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let mut out = DenseMatrix::filled(a.rows(), b.cols(), S::zero());
        gemm_acc_sr::<S>(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    #[test]
    fn tiled_gemm_matches_naive_at_tile_boundaries() {
        // Shapes straddling MR (4), NR (8), and KB (256): one below,
        // exact, one above each boundary.
        let mut rng = Xoshiro256ss::new(1);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 9),
            (7, 255, 6),
            (8, 256, 16),
            (9, 257, 17),
            (12, 300, 23),
        ] {
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            assert_eq!(run_gemm(&a, &b, &c), want, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn every_candidate_shape_matches_naive() {
        // The autotuner may pick any candidate on any host; each must
        // be exact at shapes that straddle its own tile boundaries.
        let mut rng = Xoshiro256ss::new(4);
        for &(mr, nr) in TILE_CANDIDATES {
            for &(m, k, n) in &[
                (1, 1, 1),
                (mr - 1, 3, nr - 1),
                (mr, 7, nr),
                (2 * mr + 1, 257, 2 * nr + 3),
                (3 * mr, KB, nr + 1),
            ] {
                let a = gen::dense_int(m, k, &mut rng);
                let b = gen::dense_int(k, n, &mut rng);
                let c = gen::dense_int(m, n, &mut rng);
                let mut want = a.matmul_naive(&b);
                want.add_assign(&c);
                let mut got = c.clone();
                gemm_acc_dispatch(
                    (mr, nr),
                    m,
                    k,
                    n,
                    a.as_slice(),
                    b.as_slice(),
                    got.as_mut_slice(),
                );
                assert_eq!(got, want, "shape ({mr},{nr}) at {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn autotune_report_is_sane() {
        let rep = autotune_report();
        assert_eq!(rep.candidates.len(), TILE_CANDIDATES.len());
        assert!(TILE_CANDIDATES.contains(&rep.chosen), "winner from the candidate set");
        for p in &rep.candidates {
            assert!(p.secs > 0.0, "({},{}) probed", p.mr, p.nr);
        }
        assert_eq!(tuned_shape(), rep.chosen, "cached winner is stable");
    }

    #[test]
    fn prop_tiled_gemm_matches_naive() {
        run_prop("register-tiled gemm == naive", 30, |case| {
            // Cross every tile size: m over MR, n over NR, k over KB.
            let m = 1 + case.rng.next_usize(2 * MR + 3);
            let n = 1 + case.rng.next_usize(3 * NR + 3);
            let k = 1 + case.rng.next_usize(KB + 40);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            if run_gemm(&a, &b, &c) != want {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tiled_gemm_matches_ikj_baseline() {
        run_prop("register-tiled gemm == ikj baseline", 15, |case| {
            let m = 1 + case.rng.next_usize(12);
            let n = 1 + case.rng.next_usize(20);
            let k = 1 + case.rng.next_usize(64);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let tiled = run_gemm(&a, &b, &c);
            let mut base = c.clone();
            gemm_acc_ikj(m, k, n, a.as_slice(), b.as_slice(), base.as_mut_slice());
            if tiled != base {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    /// Fractional entries whose partial sums are not exactly
    /// representable — any change in accumulation order shows up in the
    /// low bits, so equality here pins the fp order itself.
    fn fractional(rows: usize, cols: usize, rng: &mut Xoshiro256ss) -> Vec<f32> {
        (0..rows * cols)
            .map(|_| (rng.range_u64(1, 1 << 20) as f32) / 1048576.0)
            .collect()
    }

    #[test]
    fn par_gemm_bit_identical_to_sequential_on_a_pool() {
        // 70·300·40 = 840k ≥ PAR_MIN_VOLUME: the pool path splits into
        // MR-aligned panels, which must not perturb a single bit.
        let (m, k, n) = (70usize, 300usize, 40usize);
        let mut rng = Xoshiro256ss::new(9);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let c0 = fractional(m, n, &mut rng);
        let mut seq = c0.clone();
        gemm_acc(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(8);
        let stats0 = pool.stats();
        let par = pool
            .run_indexed(1, |_| {
                let mut out = c0.clone();
                gemm_acc_par(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        assert!(
            pool.stats().subtasks > stats0.subtasks,
            "tile subtasks must actually engage"
        );
        for (i, (x, y)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit mismatch at {i}");
        }
    }

    #[test]
    fn par_gemm_below_threshold_stays_sequential() {
        let (m, k, n) = (8usize, 8usize, 8usize);
        let mut rng = Xoshiro256ss::new(10);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let mut seq = vec![0.0f32; m * n];
        gemm_acc(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(4);
        let s0 = pool.stats();
        let par = pool
            .run_indexed(1, |_| {
                let mut out = vec![0.0f32; m * n];
                gemm_acc_par(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        assert_eq!(seq, par);
        assert_eq!(pool.stats().subtasks, s0.subtasks, "no tiles for a tiny GEMM");
    }

    #[test]
    fn par_semiring_gemm_bit_identical_on_a_pool() {
        let (m, k, n) = (70usize, 300usize, 40usize);
        let mut rng = Xoshiro256ss::new(11);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let mut seq = vec![0.0f32; m * n];
        gemm_acc_sr::<Arithmetic>(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(8);
        let par = pool
            .run_indexed(1, |_| {
                let mut out = vec![0.0f32; m * n];
                gemm_acc_sr_par::<Arithmetic>(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        for (x, y) in seq.iter().zip(&par) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn semiring_gemm_matches_naive_all_semirings() {
        fn check<S: Semiring>(rng: &mut Xoshiro256ss) {
            for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (8, 9, 8), (5, 257, 11)] {
                let a = gen::dense_int(m, k, rng);
                let b = gen::dense_int(k, n, rng);
                let want = a.matmul_naive_sr::<S>(&b);
                assert_eq!(
                    run_gemm_sr::<S>(&a, &b),
                    want,
                    "{} shape {m}x{k}x{n}",
                    S::name()
                );
            }
        }
        fn dist(rows: usize, cols: usize, rng: &mut Xoshiro256ss) -> DenseMatrix {
            DenseMatrix::from_fn(rows, cols, |_, _| {
                if rng.bernoulli(0.4) {
                    rng.range_u64(0, 9) as f32
                } else {
                    f32::INFINITY
                }
            })
        }
        let mut rng = Xoshiro256ss::new(2);
        check::<Arithmetic>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
        // MinPlus over distance-like matrices (∞ = no edge), so the
        // ⊕-identity actually occurs in the data.
        for &(m, k, n) in &[(3, 3, 3), (6, 9, 7), (4, 258, 5)] {
            let a = dist(m, k, &mut rng);
            let b = dist(k, n, &mut rng);
            let want = a.matmul_naive_sr::<MinPlus>(&b);
            assert_eq!(run_gemm_sr::<MinPlus>(&a, &b), want, "minplus {m}x{k}x{n}");
        }
    }

    #[test]
    fn prop_semiring_gemm_matches_naive() {
        run_prop("tiled semiring gemm == naive", 20, |case| {
            let m = 1 + case.rng.next_usize(10);
            let k = 1 + case.rng.next_usize(40);
            let n = 1 + case.rng.next_usize(14);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            if run_gemm_sr::<Arithmetic>(&a, &b) != a.matmul_naive_sr::<Arithmetic>(&b) {
                return Err(format!("arithmetic mismatch at {m}x{k}x{n}"));
            }
            // Boolean view of the same supports.
            let ab = DenseMatrix::from_fn(m, k, |i, j| if a.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            let bb = DenseMatrix::from_fn(k, n, |i, j| if b.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            if run_gemm_sr::<BoolOrAnd>(&ab, &bb) != ab.matmul_naive_sr::<BoolOrAnd>(&bb) {
                return Err(format!("boolean mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn empty_shapes_are_noops() {
        gemm_acc(0, 3, 3, &[], &[0.0; 9], &mut []);
        let mut c1 = [7.0f32; 4];
        gemm_acc(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        gemm_acc_sr::<Arithmetic>(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        gemm_acc_par(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
    }
}
