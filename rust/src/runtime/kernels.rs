//! The unified reduce-side compute kernel layer.
//!
//! Every block algebra's local multiply bottoms out here:
//!
//! * [`gemm_acc`] — register-tiled f32 GEMM (`C += A·B`): MR×NR
//!   register accumulator blocks over packed B column panels, with the
//!   k-loop tiled so each packed panel stays in cache across all row
//!   blocks. Two microkernel families share one outer loop
//!   ([`gemm_tiled`]): the portable scalar blocks and — on x86_64
//!   hosts with AVX2+FMA, detected once at runtime ([`simd_level`]) —
//!   explicit-SIMD blocks built from 256-bit `_mm256_fmadd_ps`
//!   accumulators. The dispatch shape is **autotuned** once per
//!   process: scalar candidates ([`TILE_CANDIDATES`]) and, when the
//!   host qualifies, vector candidates ([`SIMD_TILE_CANDIDATES`]) are
//!   probed at pool startup ([`ensure_tuned`], triggered by the
//!   executor's first spawn) and the winner is cached. The scalar
//!   microkernel remains the bit-exactness oracle, and setting
//!   `M3_FORCE_SCALAR=1` (read once, at first kernel use) forces it
//!   everywhere.
//! * [`gemm_acc_par`] — the same kernel with **intra-task tile
//!   parallelism**: when the calling thread is a pool task and the
//!   product volume crosses [`PAR_MIN_VOLUME`], B is packed **once**
//!   into a shareable, reference-counted [`PackedB`] artifact (the
//!   panels themselves pack in parallel as stealable subtasks), then
//!   the C rows split into MR-aligned row panels published as further
//!   subtasks, every one reusing the same packed panels instead of
//!   re-packing its own B. Panels write disjoint C row ranges, so no
//!   locking — and because every panel boundary is a multiple of the
//!   register-block height MR, each row sees exactly the accumulation
//!   order of the sequential kernel: the parallel result is
//!   **bit-identical** to [`gemm_acc`].
//! * [`gemm_acc_sr`] / [`gemm_acc_sr_par`] — generic tiled semiring
//!   GEMM (`C ⊕= A ⊗ B`) in the same `i-k-j` contiguous-row layout
//!   (rows are fully independent, so its row-panel split is trivially
//!   bit-identical); `(min,+)` and `(∨,∧)` products run through it.
//! * [`gemm_acc_ikj`] — the pre-overhaul vectorised scalar row loop,
//!   kept as the perf baseline the tiled kernel is benchmarked against
//!   (`m3 bench-kernels`).
//!
//! The naive triple loops in [`crate::matrix::DenseMatrix`]
//! (`matmul_naive` / `matmul_naive_sr`) remain the correctness oracles;
//! the property tests below pin each kernel against them bit-for-bit on
//! integer-valued inputs at shapes that straddle every tile boundary
//! (integer-valued entries make every product and partial sum exactly
//! representable, so the SIMD kernels' fused multiply-adds agree with
//! the scalar oracle's separate multiply and add **bit for bit**), and
//! the parallel entry points against their sequential twins bit-for-bit
//! on *fractional* inputs (which pins the accumulation order itself).
//!
//! The autotune probe also measures the winning kernel's effective
//! FLOP/s ([`AutotuneReport::effective_flops`]); the planner seeds
//! [`crate::simulator::ClusterProfile`]'s compute rate from it
//! (`with_probed_flops`), so plan pricing reflects the machine's real
//! post-SIMD speed rather than the paper's 2014 constants.
//!
//! The sparse counterpart (epoch-marked Gustavson SpGEMM with software
//! prefetch, the same row-panel subtask split, merged-row CSR add/sum)
//! lives with the CSR representation in [`crate::matrix::sparse`].

use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::mapreduce::executor::{current_pool_width, run_subtasks, subtask_tiling};
use crate::matrix::semiring::Semiring;

/// Default rows per register block: MR accumulator rows are held in
/// registers across the entire k-tile.
pub const MR: usize = 4;

/// Default columns per register block / packed-panel width: NR
/// accumulator lanes per row, sized for two 4-wide SIMD registers.
pub const NR: usize = 8;

/// k-tile length: the packed `KB × NR` B panel (8 KiB at f32) stays in
/// L1 while every MR-row block of A streams over it.
pub const KB: usize = 256;

/// Widest candidate NR (sizes the packed-panel scratch buffer).
pub const NR_MAX: usize = 16;

/// The scalar register-tile shapes the autotuner probes, in preference
/// order (ties go to the earlier entry). `(4, 8)` is the portable
/// default; wider NR suits 8-lane SIMD, taller MR suits register-rich
/// targets.
pub const TILE_CANDIDATES: &[(usize, usize)] = &[(4, 8), (8, 8), (4, 16), (2, 16)];

/// The explicit-SIMD register-tile shapes probed *in addition* when the
/// host has AVX2+FMA: NR is a multiple of the 8-lane `__m256` width, so
/// `(6, 16)` holds 12 vector accumulators + 2 panel vectors in the 16
/// ymm registers and `(8, 8)` trades panel reuse for a taller block.
pub const SIMD_TILE_CANDIDATES: &[(usize, usize)] = &[(6, 16), (4, 16), (8, 8)];

/// Product volume `m·k·n` below which a local GEMM is not worth
/// splitting into stealable tiles (a 64³ block product sits exactly on
/// the threshold).
pub const PAR_MIN_VOLUME: usize = 64 * 64 * 64;

/// The instruction-set level the runtime dispatcher detected, resolved
/// once per process ([`simd_level`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// No usable vector extensions (or a non-x86_64 target): the
    /// portable scalar microkernels run everywhere.
    Scalar,
    /// `M3_FORCE_SCALAR` was set: scalar microkernels forced even on
    /// capable hardware (the bit-exactness escape hatch).
    ScalarForced,
    /// AVX2 + FMA detected: 256-bit fused-multiply-add microkernels
    /// join the autotune candidate set.
    Avx2Fma,
}

impl SimdLevel {
    /// Human/JSON label for the detected features.
    pub fn features(self) -> &'static str {
        match self {
            SimdLevel::Avx2Fma => "avx2+fma",
            SimdLevel::ScalarForced => "scalar (M3_FORCE_SCALAR)",
            SimdLevel::Scalar => "scalar (portable)",
        }
    }

    /// Whether the explicit-SIMD microkernels are eligible.
    pub fn is_simd(self) -> bool {
        matches!(self, SimdLevel::Avx2Fma)
    }
}

static SIMD_LEVEL: OnceLock<SimdLevel> = OnceLock::new();

/// The detected dispatch level, resolved once: `M3_FORCE_SCALAR` (any
/// value but `0`) wins, then CPU feature detection. Cached for the
/// whole process so dispatch — and therefore bit-level results — never
/// changes mid-run.
pub fn simd_level() -> SimdLevel {
    *SIMD_LEVEL.get_or_init(|| {
        if std::env::var_os("M3_FORCE_SCALAR").is_some_and(|v| v != "0") {
            return SimdLevel::ScalarForced;
        }
        detect_simd()
    })
}

#[cfg(target_arch = "x86_64")]
fn detect_simd() -> SimdLevel {
    if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
        SimdLevel::Avx2Fma
    } else {
        SimdLevel::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_simd() -> SimdLevel {
    SimdLevel::Scalar
}

/// A dispatchable register-tile shape: the `(mr, nr)` register block
/// and which microkernel family (explicit SIMD or portable scalar)
/// runs it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelShape {
    /// Register-block rows.
    pub mr: usize,
    /// Register-block columns (= packed-panel width).
    pub nr: usize,
    /// `true` → the AVX2/FMA microkernel; `false` → the scalar oracle.
    pub simd: bool,
}

impl KernelShape {
    /// Display label, e.g. `6x16 (simd)`.
    pub fn label(&self) -> String {
        format!(
            "{}x{}{}",
            self.mr,
            self.nr,
            if self.simd { " (simd)" } else { "" }
        )
    }
}

/// Pack the `[k0, k1) × [j0, j0+nr)` tile of row-major `b` into
/// `packb` so the microkernel reads it as contiguous nr-wide rows.
#[inline]
fn pack_b_panel(
    b: &[f32],
    n: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    nr: usize,
    packb: &mut [f32],
) {
    for (kk, krow) in (k0..k1).enumerate() {
        let src = &b[krow * n + j0..krow * n + j0 + nr];
        packb[kk * nr..kk * nr + nr].copy_from_slice(src);
    }
}

/// Raw-pointer microkernel signature shared by the scalar and SIMD
/// variants, so one outer loop ([`gemm_tiled`]) drives both.
///
/// Contract (callers must uphold): `a_tile` covers the block's rows at
/// stride `lda ≥ kt`, `packb` holds `kt` packed rows of the block's
/// width, `c_tile` covers the block at stride `ldc ≥` block width —
/// and for SIMD variants the CPU features they were compiled for are
/// present (guaranteed by [`micro_for`] only returning them when
/// [`simd_level`] detected the features).
type MicroFn = unsafe fn(usize, *const f32, usize, *const f32, *mut f32, usize);

/// Scalar MRV×NRV microkernel: accumulate the k-tile product into the
/// register block, then flush it into `c_tile`. The `MRV`/`NRV` loops
/// have constant bounds, so they unroll into straight-line mul/adds.
/// This is the bit-exactness oracle the SIMD variants are pinned
/// against.
///
/// # Safety
/// See [`MicroFn`]: `a_tile`/`packb`/`c_tile` must cover the block.
unsafe fn micro_scalar<const MRV: usize, const NRV: usize>(
    kt: usize,
    a_tile: *const f32,
    lda: usize,
    packb: *const f32,
    c_tile: *mut f32,
    ldc: usize,
) {
    let mut acc = [[0.0f32; NRV]; MRV];
    for kk in 0..kt {
        let bp = packb.add(kk * NRV);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = *a_tile.add(r * lda + kk);
            for (jj, slot) in accr.iter_mut().enumerate() {
                *slot += av * *bp.add(jj);
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = c_tile.add(r * ldc);
        for (jj, &v) in accr.iter().enumerate() {
            *crow.add(jj) += v;
        }
    }
}

/// Explicit-SIMD microkernels: 256-bit FMA accumulators over the same
/// packed panels (and in the same k order) as the scalar path.
#[cfg(target_arch = "x86_64")]
mod avx {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
        _mm256_setzero_ps, _mm256_storeu_ps,
    };

    /// MRV×(NV·8) register block: NV `__m256` accumulators per row,
    /// filled by one fused multiply-add per (row, vector, k) and
    /// flushed with one add per vector. The fused op rounds once where
    /// the scalar oracle rounds twice, so general fp inputs may differ
    /// in the last bit — on exactly-representable products (the
    /// integer-valued test inputs) the two agree bit for bit.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; bounds as in
    /// [`super::MicroFn`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn microkernel_fma<const MRV: usize, const NV: usize>(
        kt: usize,
        a_tile: *const f32,
        lda: usize,
        packb: *const f32,
        c_tile: *mut f32,
        ldc: usize,
    ) {
        let mut acc = [[_mm256_setzero_ps(); NV]; MRV];
        for kk in 0..kt {
            let bp = packb.add(kk * NV * 8);
            let mut bv = [_mm256_setzero_ps(); NV];
            for (v, slot) in bv.iter_mut().enumerate() {
                *slot = _mm256_loadu_ps(bp.add(v * 8));
            }
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*a_tile.add(r * lda + kk));
                for (v, slot) in accr.iter_mut().enumerate() {
                    *slot = _mm256_fmadd_ps(av, bv[v], *slot);
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            for (v, slot) in accr.iter().enumerate() {
                let cptr = c_tile.add(r * ldc + v * 8);
                _mm256_storeu_ps(cptr, _mm256_add_ps(_mm256_loadu_ps(cptr), *slot));
            }
        }
    }

    /// Vector twin of [`super::axpby_scalar`]: `y = α·x + β·y` over
    /// 8-lane chunks, scalar tail for the remainder. Deliberately built
    /// from separate `mul`/`add` (NOT `fmadd`): elementwise IEEE
    /// multiply and add are lane-exact, so this variant is bit-for-bit
    /// identical to the scalar oracle on *all* inputs — unlike the GEMM
    /// microkernels, whose FMA only agrees on exactly-representable
    /// products.
    ///
    /// # Safety
    /// Requires AVX2 at runtime; `x` and `y` must each cover `len`
    /// floats.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpby_avx(alpha: f32, x: *const f32, beta: f32, y: *mut f32, len: usize) {
        let av = _mm256_set1_ps(alpha);
        let bv = _mm256_set1_ps(beta);
        let main = len - len % 8;
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_ps(x.add(i));
            let yv = _mm256_loadu_ps(y.add(i));
            let r = _mm256_add_ps(_mm256_mul_ps(av, xv), _mm256_mul_ps(bv, yv));
            _mm256_storeu_ps(y.add(i), r);
            i += 8;
        }
        for j in main..len {
            *y.add(j) = alpha * *x.add(j) + beta * *y.add(j);
        }
    }

    /// Register-resident FMA chain with no memory traffic: the densest
    /// sustained sequence the microkernels could possibly issue —
    /// the empirical "peak" that EXPERIMENTS.md's peak-fraction
    /// methodology divides by. Returns `(flops, sink)`.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn peak_fma(iters: usize) -> (f64, f32) {
        const VECS: usize = 10;
        let x = _mm256_set1_ps(std::hint::black_box(0.999_f32));
        let y = _mm256_set1_ps(std::hint::black_box(1.0e-3_f32));
        let mut acc = [_mm256_setzero_ps(); VECS];
        for _ in 0..iters {
            for slot in acc.iter_mut() {
                // Fixed point ≈ y/(1-x): stays bounded for any iters.
                *slot = _mm256_fmadd_ps(*slot, x, y);
            }
        }
        let mut buf = [0.0f32; 8];
        let mut sink = 0.0f32;
        for slot in &acc {
            _mm256_storeu_ps(buf.as_mut_ptr(), *slot);
            sink += buf.iter().sum::<f32>();
        }
        ((2 * VECS * 8 * iters) as f64, sink)
    }
}

/// Resolve the microkernel for a dispatch shape. SIMD shapes resolve
/// to the FMA variants only when [`simd_level`] actually detected the
/// features (so a forged `simd: true` on incapable hardware degrades
/// to the scalar twin instead of executing illegal instructions);
/// unknown shapes fall back to the default `(MR, NR)` scalar block.
fn micro_for(shape: KernelShape) -> MicroFn {
    #[cfg(target_arch = "x86_64")]
    {
        if shape.simd && simd_level().is_simd() {
            return match (shape.mr, shape.nr) {
                (6, 16) => avx::microkernel_fma::<6, 2>,
                (8, 8) => avx::microkernel_fma::<8, 1>,
                _ => avx::microkernel_fma::<4, 2>,
            };
        }
    }
    match (shape.mr, shape.nr) {
        (8, 8) => micro_scalar::<8, 8>,
        (6, 16) => micro_scalar::<6, 16>,
        (4, 16) => micro_scalar::<4, 16>,
        (2, 16) => micro_scalar::<2, 16>,
        _ => micro_scalar::<MR, NR>,
    }
}

/// All full-width B panels of one multiply, packed once and shared:
/// [`gemm_acc_par`] wraps one in an [`Arc`] and every row-panel
/// subtask reads the same reference-counted artifact instead of
/// re-packing its own copy of B.
///
/// Layout: panel `(t, p)` — k-tile `t`, `nr`-wide column panel `p` —
/// lives at offset `(t·panels + p)·KB·nr`, stored as `kt` contiguous
/// `nr`-wide rows (a short final k-tile leaves its tail rows unused).
/// The trailing `n % nr` columns are *not* packed; the outer loop
/// reads them straight from `b`, exactly as the stack-packing path
/// does.
pub struct PackedB {
    nr: usize,
    k: usize,
    panels: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack every full `nr`-wide panel of row-major `b` (`k×n`). When
    /// the caller is a pool task, the `(k-tile, panel)` pairs pack in
    /// parallel as stealable subtasks
    /// ([`crate::mapreduce::executor::run_subtasks`] runs them inline
    /// otherwise); the packed bytes are identical either way.
    pub fn pack(b: &[f32], k: usize, n: usize, nr: usize) -> Self {
        debug_assert_eq!(b.len(), k * n);
        let n_main = n - n % nr;
        let panels = n_main / nr;
        let ktiles = k.div_ceil(KB);
        let stride = KB * nr;
        let mut data = vec![0.0f32; ktiles * panels * stride];
        if panels > 0 && ktiles > 0 {
            let dp = SendPtr(data.as_mut_ptr());
            run_subtasks(ktiles * panels, |idx| {
                let t = idx / panels;
                let p = idx % panels;
                let k0 = t * KB;
                let k1 = (k0 + KB).min(k);
                // SAFETY: each (t, p) pair owns a disjoint `stride`
                // slice of `data`, and `run_subtasks` joins before
                // `data` is read.
                let dst =
                    unsafe { std::slice::from_raw_parts_mut(dp.0.add(idx * stride), stride) };
                pack_b_panel(b, n, k0, k1, p * nr, nr, dst);
            });
        }
        PackedB {
            nr,
            k,
            panels,
            data,
        }
    }

    /// The packed `(k-tile t, panel p)` slice: `kt` rows of `nr`.
    fn panel(&self, t: usize, p: usize) -> &[f32] {
        let kt = (self.k - t * KB).min(KB);
        let base = (t * self.panels + p) * KB * self.nr;
        &self.data[base..base + kt * self.nr]
    }
}

/// The shared packed-panel outer loop: k-tiles × column panels × row
/// blocks. Full tiles go through `shape`'s microkernel; the row
/// remainder runs against the packed panel and the column remainder
/// through the scalar row loop, so every shape is supported and both
/// microkernel families see the identical loop structure (and
/// therefore the identical per-element accumulation order).
///
/// `packed`: pre-packed panels to reuse ([`PackedB`]); `None` packs
/// each panel into stack scratch on the fly. The packed panel bytes
/// are the same either way, so the two modes are bit-identical.
fn gemm_tiled(
    shape: KernelShape,
    (m, k, n): (usize, usize, usize),
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    packed: Option<&PackedB>,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let (mr, nr) = (shape.mr, shape.nr);
    let micro = micro_for(shape);
    let n_main = n - n % nr; // columns covered by full packed panels
    let m_main = m - m % mr; // rows covered by full register blocks
    let mut scratch = [0.0f32; KB * NR_MAX];
    let mut k0 = 0;
    let mut t = 0; // k-tile index
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        let kt = k1 - k0;
        let mut j0 = 0;
        let mut p = 0; // panel index
        while j0 < n_main {
            let panel: &[f32] = match packed {
                Some(pb) => pb.panel(t, p),
                None => {
                    // One pack per (k-tile, panel) amortised over all
                    // m/mr register blocks.
                    pack_b_panel(b, n, k0, k1, j0, nr, &mut scratch);
                    &scratch[..kt * nr]
                }
            };
            let mut i0 = 0;
            while i0 < m_main {
                // SAFETY: the tile is in bounds by construction
                // (i0+mr ≤ m, j0+nr ≤ n, panel holds kt·nr floats) and
                // `micro_for` only hands out SIMD kernels on hosts
                // whose features were detected.
                unsafe {
                    micro(
                        kt,
                        a.as_ptr().add(i0 * k + k0),
                        k,
                        panel.as_ptr(),
                        c.as_mut_ptr().add(i0 * n + j0),
                        n,
                    );
                }
                i0 += mr;
            }
            // Row remainder against the packed panel.
            for i in m_main..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j0 + nr];
                for kk in 0..kt {
                    let av = arow[k0 + kk];
                    let bp = &panel[kk * nr..kk * nr + nr];
                    for (cv, &bv) in crow.iter_mut().zip(bp) {
                        *cv += av * bv;
                    }
                }
            }
            j0 += nr;
            p += 1;
        }
        // Column remainder (n % nr) for all rows: scalar row loop. No
        // zero-skip here — the microkernel path has none, so every
        // output column sees identical `c += a*b` IEEE semantics.
        if n_main < n {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in n_main..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
        k0 = k1;
        t += 1;
    }
}

/// One probed candidate of the dispatch autotune.
#[derive(Debug, Clone, Copy)]
pub struct TileProbe {
    /// Register-block rows.
    pub mr: usize,
    /// Register-block columns.
    pub nr: usize,
    /// Explicit-SIMD microkernel (`false` = scalar).
    pub simd: bool,
    /// Best-of-reps seconds for the probe GEMM.
    pub secs: f64,
}

/// Result of the one-shot dispatch autotune, cached for the whole
/// process and surfaced by `m3 bench-kernels --json`.
#[derive(Debug, Clone)]
pub struct AutotuneReport {
    /// The winning shape every `gemm_acc`-family call uses.
    pub chosen: KernelShape,
    /// Instruction-set features the runtime dispatcher detected
    /// ([`SimdLevel::features`]).
    pub features: &'static str,
    /// Flops of one probe GEMM (per-candidate GFLOP/s =
    /// `probe_flops / secs / 1e9`).
    pub probe_flops: f64,
    /// Measured effective throughput of the winning microkernel on the
    /// probe GEMM, FLOP/s — what
    /// [`crate::simulator::ClusterProfile::with_probed_flops`] seeds
    /// the planner's compute rate with.
    pub effective_flops: f64,
    /// All probed candidates (scalar first, then any SIMD) with their
    /// timings.
    pub candidates: Vec<TileProbe>,
}

static TUNED: OnceLock<AutotuneReport> = OnceLock::new();

fn probe_shapes() -> AutotuneReport {
    use crate::util::rng::Xoshiro256ss;
    // One full k-tile, several register blocks in each dimension (96
    // divides by every candidate MR, 64 by every NR) — large enough to
    // rank shapes, small enough to probe in milliseconds at pool
    // startup.
    const M: usize = 96;
    const K: usize = 256;
    const N: usize = 64;
    const REPS: usize = 3;
    let level = simd_level();
    let mut rng = Xoshiro256ss::new(0xA070);
    let a: Vec<f32> = (0..M * K).map(|_| rng.range_u64(0, 255) as f32 / 16.0).collect();
    let b: Vec<f32> = (0..K * N).map(|_| rng.range_u64(0, 255) as f32 / 16.0).collect();
    let mut shapes: Vec<KernelShape> = TILE_CANDIDATES
        .iter()
        .map(|&(mr, nr)| KernelShape {
            mr,
            nr,
            simd: false,
        })
        .collect();
    if level.is_simd() {
        shapes.extend(SIMD_TILE_CANDIDATES.iter().map(|&(mr, nr)| KernelShape {
            mr,
            nr,
            simd: true,
        }));
    }
    let mut candidates = Vec::with_capacity(shapes.len());
    let mut chosen = shapes[0];
    let mut best = f64::INFINITY;
    for shape in shapes {
        let mut c = vec![0.0f32; M * N];
        gemm_tiled(shape, (M, K, N), &a, &b, &mut c, None); // warm-up
        let mut secs = f64::INFINITY;
        for _ in 0..REPS {
            let t = Instant::now();
            gemm_tiled(shape, (M, K, N), &a, &b, &mut c, None);
            secs = secs.min(t.elapsed().as_secs_f64());
        }
        std::hint::black_box(&c);
        candidates.push(TileProbe {
            mr: shape.mr,
            nr: shape.nr,
            simd: shape.simd,
            secs,
        });
        if secs < best {
            best = secs;
            chosen = shape;
        }
    }
    let probe_flops = 2.0 * (M * K * N) as f64;
    AutotuneReport {
        chosen,
        features: level.features(),
        probe_flops,
        effective_flops: probe_flops / best.max(1e-12),
        candidates,
    }
}

/// The cached autotune result (probing on first use).
pub fn autotune_report() -> &'static AutotuneReport {
    TUNED.get_or_init(probe_shapes)
}

/// The dispatch shape in use.
pub fn tuned_shape() -> KernelShape {
    autotune_report().chosen
}

/// The winning microkernel's measured effective FLOP/s on the probe
/// GEMM — the per-slot rate `m3 plan`/`m3 serve` seed their
/// [`crate::simulator::ClusterProfile`] with.
pub fn measured_flops_per_slot() -> f64 {
    autotune_report().effective_flops
}

/// Run feature detection + the autotune probe now if they have not run
/// yet. Called at pool startup ([`crate::mapreduce::executor::Pool`]
/// spawning its workers) so the probe's cost lands outside timed
/// rounds.
pub fn ensure_tuned() {
    let _ = autotune_report();
}

/// Empirical peak FLOP/s of the detected dispatch level: a
/// register-resident multiply-add chain with no memory traffic, timed
/// best-of-3. On AVX2+FMA hosts this is the 256-bit FMA chain; on
/// scalar dispatch it is the plain mul+add loop (whatever the compiler
/// sustains from registers). `m3 bench-kernels` divides the measured
/// GEMM rate by this to report `peak_fraction`.
pub fn measure_peak_flops() -> f64 {
    const ITERS: usize = 1 << 16;
    const REPS: usize = 3;
    let mut best = f64::INFINITY;
    let mut flops = 1.0;
    for _ in 0..REPS {
        let t = Instant::now();
        let (f, sink) = peak_run(ITERS);
        best = best.min(t.elapsed().as_secs_f64());
        flops = f;
        std::hint::black_box(sink);
    }
    flops / best.max(1e-12)
}

fn peak_run(iters: usize) -> (f64, f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_level().is_simd() {
            // SAFETY: AVX2+FMA verified by `simd_level`.
            return unsafe { avx::peak_fma(iters) };
        }
    }
    peak_scalar(iters)
}

fn peak_scalar(iters: usize) -> (f64, f32) {
    const LANES: usize = 16;
    let x = std::hint::black_box(0.999_f32);
    let y = std::hint::black_box(1.0e-3_f32);
    let mut acc = [0.0f32; LANES];
    for _ in 0..iters {
        for slot in acc.iter_mut() {
            *slot = *slot * x + y;
        }
    }
    ((2 * LANES * iters) as f64, acc.iter().sum())
}

/// Register-tiled `c += a·b` on raw row-major slices, at the autotuned
/// dispatch shape.
///
/// `a`: `m×k`, `b`: `k×n`, `c`: `m×n`. Deterministic within a process:
/// the tuned shape is probed once and cached, so repeated runs produce
/// bit-identical results.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_tiled(tuned_shape(), (m, k, n), a, b, c, None);
}

/// [`gemm_acc`] at an explicit dispatch shape — how `m3 bench-kernels`
/// races the chosen dispatch against the scalar candidates on the same
/// inputs, and how the tests pin each SIMD microkernel against its
/// scalar twin. SIMD shapes silently degrade to the scalar twin when
/// the host lacks the features ([`micro_for`]), so any shape is safe
/// to pass.
pub fn gemm_acc_with_shape(
    shape: KernelShape,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_tiled(shape, (m, k, n), a, b, c, None);
}

/// Disjoint-panel output pointer ferried into tile subtasks. Each
/// subtask manufactures a `&mut` slice over its own row range only.
struct SendPtr(*mut f32);
// SAFETY: subtasks write disjoint row panels (see `gemm_acc_par` and
// `PackedB::pack`), and the spawning call joins before the buffer is
// touched again.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// [`gemm_acc`] with intra-task tile parallelism: when the calling
/// thread is a task of a multi-worker pool and `m·k·n ≥`
/// [`PAR_MIN_VOLUME`], B's panels are packed once — in parallel, as
/// stealable subtasks — into a reference-counted [`PackedB`], then the
/// C rows split into MR-aligned row panels published as subtasks that
/// all share those packed panels; idle workers steal panels instead of
/// waiting out one oversized local multiply, and no subtask re-packs
/// B.
///
/// **Ownership rule:** each panel owns a disjoint `[i0, i1) × n` slice
/// of `c` — no two subtasks ever touch the same C element, so there is
/// no locking and no non-determinism. **Bit-identity:** every panel
/// boundary is a multiple of the register-block height `mr`, so each
/// row takes exactly the register/remainder path it takes in the
/// sequential kernel, and the pre-packed panels hold exactly the bytes
/// the sequential kernel packs on the fly — the result is bit-for-bit
/// equal to [`gemm_acc`]'s regardless of worker count or stealing
/// order.
pub fn gemm_acc_par(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let width = current_pool_width();
    let shape = tuned_shape();
    if !subtask_tiling() || width <= 1 || m < 2 * shape.mr || m * k * n < PAR_MIN_VOLUME {
        gemm_tiled(shape, (m, k, n), a, b, c, None);
        return;
    }
    // Pack B off the critical path: one shared artifact, packed in
    // parallel, reused by every row-panel subtask below.
    let packed = Arc::new(PackedB::pack(b, k, n, shape.nr));
    // MR-aligned row panels, about two per worker so stealing can
    // rebalance mid-flight.
    let blocks = m / shape.mr;
    let panels = blocks.min(2 * width);
    let rows_pp = blocks.div_ceil(panels) * shape.mr;
    let num_panels = m.div_ceil(rows_pp);
    let cp = SendPtr(c.as_mut_ptr());
    run_subtasks(num_panels, |p| {
        let i0 = p * rows_pp;
        let i1 = (i0 + rows_pp).min(m);
        // SAFETY: panels cover disjoint row ranges [i0, i1); each
        // subtask writes only its own C rows, and `run_subtasks` joins
        // before `c` is read again.
        let cpan = unsafe { std::slice::from_raw_parts_mut(cp.0.add(i0 * n), (i1 - i0) * n) };
        gemm_tiled(
            shape,
            (i1 - i0, k, n),
            &a[i0 * k..i1 * k],
            b,
            cpan,
            Some(packed.as_ref()),
        );
    });
}

/// The pre-overhaul kernel: scalar `i-k-j` row loop with k-tiling, no
/// register blocking or packing. Kept verbatim — including its
/// original `KB = 64` k-tile — as the perf baseline for
/// `m3 bench-kernels`, so `speedup_vs_ikj` is a true before/after
/// comparison; [`gemm_acc`] must beat it.
pub fn gemm_acc_ikj(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    const KB_IKJ: usize = 64; // the shipped pre-overhaul k-tile
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB_IKJ).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled semiring GEMM `c ⊕= a ⊗ b` on raw row-major slices.
///
/// Same `i-k-j` contiguous-row layout and k-tiling as [`gemm_acc`]: the
/// inner loop walks rows of `b` and `c` as slices, so `⊕`/`⊗` pairs
/// that lower to machine ops (`min`+`add` for the tropical semiring)
/// auto-vectorise — unlike the `get()`-based naive triple loop.
///
/// `c` must be initialised by the caller (to `S::zero()` for a fresh
/// product). Entries of `a` equal to `S::zero()` are skipped: `zero`
/// is the ⊗-annihilator and the ⊕-identity in every semiring, so the
/// skip is exact.
pub fn gemm_acc_sr<S: Semiring>(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if S::is_zero(av) {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv = S::add(*cv, S::mul(av, bv));
                }
            }
        }
        k0 = k1;
    }
}

/// [`gemm_acc_sr`] with the same stealable row-panel split as
/// [`gemm_acc_par`]. The semiring kernel's rows are fully independent
/// (no register blocking), so any row split is trivially bit-identical
/// to the sequential kernel.
pub fn gemm_acc_sr_par<S: Semiring>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let width = current_pool_width();
    if !subtask_tiling() || width <= 1 || m < 2 || m * k * n < PAR_MIN_VOLUME {
        gemm_acc_sr::<S>(m, k, n, a, b, c);
        return;
    }
    let panels = m.min(2 * width);
    let rows_pp = m.div_ceil(panels);
    let num_panels = m.div_ceil(rows_pp);
    let cp = SendPtr(c.as_mut_ptr());
    run_subtasks(num_panels, |p| {
        let i0 = p * rows_pp;
        let i1 = (i0 + rows_pp).min(m);
        // SAFETY: disjoint row panels; see `gemm_acc_par`.
        let cpan = unsafe { std::slice::from_raw_parts_mut(cp.0.add(i0 * n), (i1 - i0) * n) };
        gemm_acc_sr::<S>(i1 - i0, k, n, &a[i0 * k..i1 * k], b, cpan);
    });
}

/// Scalar oracle for the block linear combination `y = α·x + β·y`.
///
/// Written as explicit `mul`/`mul`/`add` per element; Rust never
/// contracts float expressions into FMAs, so the vector twin
/// ([`axpby`]'s AVX2 path, built from `_mm256_mul_ps` +
/// `_mm256_add_ps`) produces **bit-identical** results on every input,
/// fractional included — elementwise IEEE ops have no accumulation
/// order to perturb. This is the kernel the Strassen block algebra's
/// reduce-side T/S/C combinations bottom out in.
pub fn axpby_scalar(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpby operands must match");
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = alpha * xv + beta * *yv;
    }
}

/// SIMD-aware block linear combination `y = α·x + β·y`, dispatched
/// once per process like the GEMM microkernels ([`simd_level`]):
/// AVX2 hosts run the 8-lane vector twin, everything else (and
/// `M3_FORCE_SCALAR=1`) the scalar oracle. The two paths are
/// bit-for-bit identical on all inputs (see [`axpby_scalar`]), so the
/// dispatch never changes results.
///
/// With `α, β ∈ {0, ±1}` this is the exact block add/sub/copy/negate
/// the Strassen schedule needs: multiplying by `±1`/`0` is exact in
/// IEEE arithmetic, so e.g. `axpby(-1, x, 1, y)` is precisely `y - x`.
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpby operands must match");
    #[cfg(target_arch = "x86_64")]
    {
        if simd_level().is_simd() {
            // SAFETY: AVX2 verified by `simd_level`; both slices cover
            // `len` floats by the assert above.
            unsafe { avx::axpby_avx(alpha, x.as_ptr(), beta, y.as_mut_ptr(), y.len()) };
            return;
        }
    }
    axpby_scalar(alpha, x, beta, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::executor::Pool;
    use crate::matrix::gen;
    use crate::matrix::semiring::{Arithmetic, BoolOrAnd, MinPlus};
    use crate::matrix::DenseMatrix;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    /// Run the f32 kernel on matrices and return the result.
    fn run_gemm(a: &DenseMatrix, b: &DenseMatrix, c: &DenseMatrix) -> DenseMatrix {
        let mut out = c.clone();
        gemm_acc(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    fn run_gemm_sr<S: Semiring>(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let mut out = DenseMatrix::filled(a.rows(), b.cols(), S::zero());
        gemm_acc_sr::<S>(
            a.rows(),
            a.cols(),
            b.cols(),
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
        );
        out
    }

    #[test]
    fn tiled_gemm_matches_naive_at_tile_boundaries() {
        // Shapes straddling MR (4), NR (8), and KB (256): one below,
        // exact, one above each boundary.
        let mut rng = Xoshiro256ss::new(1);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 9),
            (7, 255, 6),
            (8, 256, 16),
            (9, 257, 17),
            (12, 300, 23),
        ] {
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            assert_eq!(run_gemm(&a, &b, &c), want, "shape {m}x{k}x{n}");
        }
    }

    /// Every dispatchable shape — scalar and, on capable hosts, SIMD.
    fn all_shapes() -> Vec<KernelShape> {
        let mut shapes: Vec<KernelShape> = TILE_CANDIDATES
            .iter()
            .map(|&(mr, nr)| KernelShape {
                mr,
                nr,
                simd: false,
            })
            .collect();
        if simd_level().is_simd() {
            shapes.extend(SIMD_TILE_CANDIDATES.iter().map(|&(mr, nr)| KernelShape {
                mr,
                nr,
                simd: true,
            }));
        }
        shapes
    }

    #[test]
    fn every_candidate_shape_matches_naive() {
        // The autotuner may pick any candidate on any host; each must
        // be exact at shapes that straddle its own tile boundaries.
        let mut rng = Xoshiro256ss::new(4);
        for shape in all_shapes() {
            let (mr, nr) = (shape.mr, shape.nr);
            for &(m, k, n) in &[
                (1, 1, 1),
                (mr - 1, 3, nr - 1),
                (mr, 7, nr),
                (2 * mr + 1, 257, 2 * nr + 3),
                (3 * mr, KB, nr + 1),
            ] {
                let a = gen::dense_int(m, k, &mut rng);
                let b = gen::dense_int(k, n, &mut rng);
                let c = gen::dense_int(m, n, &mut rng);
                let mut want = a.matmul_naive(&b);
                want.add_assign(&c);
                let mut got = c.clone();
                gemm_acc_with_shape(
                    shape,
                    m,
                    k,
                    n,
                    a.as_slice(),
                    b.as_slice(),
                    got.as_mut_slice(),
                );
                assert_eq!(got, want, "shape {} at {m}x{k}x{n}", shape.label());
            }
        }
    }

    #[test]
    fn simd_microkernels_bit_match_the_scalar_oracle() {
        // Feature-matrix equivalence: each SIMD microkernel against its
        // scalar twin at tile-straddling shapes, on integer inputs
        // (entries in [-4, 4], so products cancel to exact zeros and
        // every partial sum is exactly representable — FMA and mul+add
        // agree bit for bit).
        if !simd_level().is_simd() {
            return; // no SIMD on this host (or forced scalar)
        }
        let mut rng = Xoshiro256ss::new(7);
        for &(mr, nr) in SIMD_TILE_CANDIDATES {
            let simd = KernelShape { mr, nr, simd: true };
            let scalar = KernelShape {
                mr,
                nr,
                simd: false,
            };
            for &(m, k, n) in &[
                (1, 1, 1),
                (mr - 1, KB + 1, nr - 1), // row/col remainders straddling the k-tile
                (mr, 7, nr),              // exactly one register block
                (2 * mr + 1, 257, 2 * nr + 3),
                (3 * mr, KB, nr + 1),
            ] {
                let a = gen::dense_int(m, k, &mut rng);
                let b = gen::dense_int(k, n, &mut rng);
                let c = gen::dense_int(m, n, &mut rng);
                let mut got_simd = c.clone();
                gemm_acc_with_shape(
                    simd,
                    m,
                    k,
                    n,
                    a.as_slice(),
                    b.as_slice(),
                    got_simd.as_mut_slice(),
                );
                let mut got_scalar = c.clone();
                gemm_acc_with_shape(
                    scalar,
                    m,
                    k,
                    n,
                    a.as_slice(),
                    b.as_slice(),
                    got_scalar.as_mut_slice(),
                );
                for (i, (x, y)) in got_simd
                    .as_slice()
                    .iter()
                    .zip(got_scalar.as_slice())
                    .enumerate()
                {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "simd {mr}x{nr} vs scalar oracle at {m}x{k}x{n}, element {i}"
                    );
                }
                let mut want = a.matmul_naive(&b);
                want.add_assign(&c);
                assert_eq!(got_simd, want, "simd {mr}x{nr} vs naive at {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn forced_scalar_env_pins_the_dispatch() {
        // Dispatch is resolved once per process, so this asserts the
        // contract in whichever environment the suite runs: under
        // M3_FORCE_SCALAR the chosen kernel must be scalar (the CI
        // forced-scalar job runs the whole suite this way).
        let forced = std::env::var_os("M3_FORCE_SCALAR").is_some_and(|v| v != "0");
        let rep = autotune_report();
        assert_eq!(rep.features, simd_level().features());
        if forced {
            assert_eq!(simd_level(), SimdLevel::ScalarForced);
            assert!(!rep.chosen.simd, "forced scalar must never pick SIMD");
            assert!(rep.candidates.iter().all(|p| !p.simd));
        }
        if !simd_level().is_simd() {
            assert!(!rep.chosen.simd);
        }
    }

    #[test]
    fn autotune_report_is_sane() {
        let rep = autotune_report();
        assert!(rep.candidates.len() >= TILE_CANDIDATES.len());
        assert!(
            rep.candidates
                .iter()
                .any(|p| (p.mr, p.nr, p.simd) == (rep.chosen.mr, rep.chosen.nr, rep.chosen.simd)),
            "winner from the candidate set"
        );
        for p in &rep.candidates {
            assert!(p.secs > 0.0, "({},{}) probed", p.mr, p.nr);
        }
        assert_eq!(tuned_shape(), rep.chosen, "cached winner is stable");
        assert!(rep.effective_flops > 0.0, "probe measured a flop rate");
        assert!(rep.probe_flops > 0.0);
        assert!(
            measured_flops_per_slot() == rep.effective_flops,
            "profile seeding reads the probe"
        );
    }

    #[test]
    fn peak_probe_measures_something() {
        let peak = measure_peak_flops();
        assert!(peak > 0.0 && peak.is_finite());
    }

    #[test]
    fn prop_tiled_gemm_matches_naive() {
        run_prop("register-tiled gemm == naive", 30, |case| {
            // Cross every tile size: m over MR, n over NR, k over KB.
            let m = 1 + case.rng.next_usize(2 * MR + 3);
            let n = 1 + case.rng.next_usize(3 * NR + 3);
            let k = 1 + case.rng.next_usize(KB + 40);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let mut want = a.matmul_naive(&b);
            want.add_assign(&c);
            if run_gemm(&a, &b, &c) != want {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tiled_gemm_matches_ikj_baseline() {
        run_prop("register-tiled gemm == ikj baseline", 15, |case| {
            let m = 1 + case.rng.next_usize(12);
            let n = 1 + case.rng.next_usize(20);
            let k = 1 + case.rng.next_usize(64);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            let c = gen::dense_int(m, n, &mut rng);
            let tiled = run_gemm(&a, &b, &c);
            let mut base = c.clone();
            gemm_acc_ikj(m, k, n, a.as_slice(), b.as_slice(), base.as_mut_slice());
            if tiled != base {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    /// Fractional entries whose partial sums are not exactly
    /// representable — any change in accumulation order shows up in the
    /// low bits, so equality here pins the fp order itself.
    fn fractional(rows: usize, cols: usize, rng: &mut Xoshiro256ss) -> Vec<f32> {
        (0..rows * cols)
            .map(|_| (rng.range_u64(1, 1 << 20) as f32) / 1048576.0)
            .collect()
    }

    #[test]
    fn prepacked_panels_bit_identical_to_stack_packing() {
        // The shared PackedB artifact must reproduce the on-the-fly
        // stack packing bit for bit — on fractional inputs, for every
        // dispatchable shape, at a shape with row, column, and k-tile
        // remainders.
        let (m, k, n) = (13usize, 300usize, 21usize);
        let mut rng = Xoshiro256ss::new(21);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let c0 = fractional(m, n, &mut rng);
        for shape in all_shapes() {
            let mut plain = c0.clone();
            gemm_tiled(shape, (m, k, n), &a, &b, &mut plain, None);
            let packed = PackedB::pack(&b, k, n, shape.nr);
            let mut pre = c0.clone();
            gemm_tiled(shape, (m, k, n), &a, &b, &mut pre, Some(&packed));
            for (i, (x, y)) in plain.iter().zip(&pre).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "shape {} element {i}",
                    shape.label()
                );
            }
        }
    }

    #[test]
    fn par_gemm_bit_identical_to_sequential_on_a_pool() {
        // 70·300·40 = 840k ≥ PAR_MIN_VOLUME: the pool path packs B
        // once (in parallel) and splits C into MR-aligned panels, which
        // must not perturb a single bit.
        let (m, k, n) = (70usize, 300usize, 40usize);
        let mut rng = Xoshiro256ss::new(9);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let c0 = fractional(m, n, &mut rng);
        let mut seq = c0.clone();
        gemm_acc(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(8);
        let stats0 = pool.stats();
        let par = pool
            .run_indexed(1, |_| {
                let mut out = c0.clone();
                gemm_acc_par(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        assert!(
            pool.stats().subtasks > stats0.subtasks,
            "tile subtasks must actually engage"
        );
        for (i, (x, y)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit mismatch at {i}");
        }
    }

    #[test]
    fn par_gemm_below_threshold_stays_sequential() {
        let (m, k, n) = (8usize, 8usize, 8usize);
        let mut rng = Xoshiro256ss::new(10);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let mut seq = vec![0.0f32; m * n];
        gemm_acc(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(4);
        let s0 = pool.stats();
        let par = pool
            .run_indexed(1, |_| {
                let mut out = vec![0.0f32; m * n];
                gemm_acc_par(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        assert_eq!(seq, par);
        assert_eq!(pool.stats().subtasks, s0.subtasks, "no tiles for a tiny GEMM");
    }

    #[test]
    fn par_semiring_gemm_bit_identical_on_a_pool() {
        let (m, k, n) = (70usize, 300usize, 40usize);
        let mut rng = Xoshiro256ss::new(11);
        let a = fractional(m, k, &mut rng);
        let b = fractional(k, n, &mut rng);
        let mut seq = vec![0.0f32; m * n];
        gemm_acc_sr::<Arithmetic>(m, k, n, &a, &b, &mut seq);
        let pool = Pool::new(8);
        let par = pool
            .run_indexed(1, |_| {
                let mut out = vec![0.0f32; m * n];
                gemm_acc_sr_par::<Arithmetic>(m, k, n, &a, &b, &mut out);
                out
            })
            .remove(0);
        for (x, y) in seq.iter().zip(&par) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn semiring_gemm_matches_naive_all_semirings() {
        fn check<S: Semiring>(rng: &mut Xoshiro256ss) {
            for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (8, 9, 8), (5, 257, 11)] {
                let a = gen::dense_int(m, k, rng);
                let b = gen::dense_int(k, n, rng);
                let want = a.matmul_naive_sr::<S>(&b);
                assert_eq!(
                    run_gemm_sr::<S>(&a, &b),
                    want,
                    "{} shape {m}x{k}x{n}",
                    S::name()
                );
            }
        }
        fn dist(rows: usize, cols: usize, rng: &mut Xoshiro256ss) -> DenseMatrix {
            DenseMatrix::from_fn(rows, cols, |_, _| {
                if rng.bernoulli(0.4) {
                    rng.range_u64(0, 9) as f32
                } else {
                    f32::INFINITY
                }
            })
        }
        let mut rng = Xoshiro256ss::new(2);
        check::<Arithmetic>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
        // MinPlus over distance-like matrices (∞ = no edge), so the
        // ⊕-identity actually occurs in the data.
        for &(m, k, n) in &[(3, 3, 3), (6, 9, 7), (4, 258, 5)] {
            let a = dist(m, k, &mut rng);
            let b = dist(k, n, &mut rng);
            let want = a.matmul_naive_sr::<MinPlus>(&b);
            assert_eq!(run_gemm_sr::<MinPlus>(&a, &b), want, "minplus {m}x{k}x{n}");
        }
    }

    #[test]
    fn prop_semiring_gemm_matches_naive() {
        run_prop("tiled semiring gemm == naive", 20, |case| {
            let m = 1 + case.rng.next_usize(10);
            let k = 1 + case.rng.next_usize(40);
            let n = 1 + case.rng.next_usize(14);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(m, k, &mut rng);
            let b = gen::dense_int(k, n, &mut rng);
            if run_gemm_sr::<Arithmetic>(&a, &b) != a.matmul_naive_sr::<Arithmetic>(&b) {
                return Err(format!("arithmetic mismatch at {m}x{k}x{n}"));
            }
            // Boolean view of the same supports.
            let ab = DenseMatrix::from_fn(m, k, |i, j| if a.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            let bb = DenseMatrix::from_fn(k, n, |i, j| if b.get(i, j) != 0.0 { 1.0 } else { 0.0 });
            if run_gemm_sr::<BoolOrAnd>(&ab, &bb) != ab.matmul_naive_sr::<BoolOrAnd>(&bb) {
                return Err(format!("boolean mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_axpby_simd_bit_matches_the_scalar_oracle() {
        // Unlike the GEMM FMA pins, this holds on arbitrary fractional
        // inputs: axpby is elementwise mul/mul/add in both dispatches,
        // so there is no rounding or ordering freedom at all. Lengths
        // straddle the 8-lane vector width.
        run_prop("axpby dispatch == scalar oracle", 40, |case| {
            let len = 1 + case.rng.next_usize(70);
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let x = fractional(1, len, &mut rng);
            let y0 = fractional(1, len, &mut rng);
            let coeffs = [1.0f32, -1.0, 0.0, 0.5, -2.75];
            let alpha = coeffs[rng.range_u64(0, coeffs.len() as u64 - 1) as usize];
            let beta = coeffs[rng.range_u64(0, coeffs.len() as u64 - 1) as usize];
            let mut got = y0.clone();
            axpby(alpha, &x, beta, &mut got);
            let mut want = y0.clone();
            axpby_scalar(alpha, &x, beta, &mut want);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    return Err(format!(
                        "axpby({alpha},{beta}) len {len}: bit mismatch at {i}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn axpby_signed_combinations_are_exact() {
        // The Strassen coefficients are all ±1: check the add, sub,
        // copy, and negate cases against hand arithmetic.
        let x = [1.5f32, -2.0, 3.25, 0.0, 7.0];
        let y0 = [10.0f32, 20.0, 30.0, 40.0, 50.0];
        let mut y = y0;
        axpby(1.0, &x, 1.0, &mut y); // y + x
        assert_eq!(y, [11.5, 18.0, 33.25, 40.0, 57.0]);
        let mut y = y0;
        axpby(-1.0, &x, 1.0, &mut y); // y - x
        assert_eq!(y, [8.5, 22.0, 26.75, 40.0, 43.0]);
        let mut y = y0;
        axpby(1.0, &x, 0.0, &mut y); // copy
        assert_eq!(y, x);
        let mut y = y0;
        axpby(-1.0, &x, 0.0, &mut y); // negate
        assert_eq!(y, [-1.5, 2.0, -3.25, 0.0, -7.0]);
    }

    #[test]
    fn empty_shapes_are_noops() {
        gemm_acc(0, 3, 3, &[], &[0.0; 9], &mut []);
        let mut c1 = [7.0f32; 4];
        gemm_acc(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        gemm_acc_sr::<Arithmetic>(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        gemm_acc_par(2, 0, 2, &[], &[], &mut c1);
        assert_eq!(c1, [7.0; 4]);
        let pb = PackedB::pack(&[], 0, 4, 8);
        assert!(pb.data.is_empty());
    }
}
