//! The auto-planner: cost-model plan search over the paper's tradeoff
//! space.
//!
//! The paper's central claim (§1) is that multi-round algorithms win by
//! "suitably setting the round number according to the execution
//! context". This module makes that operational: for a job's *shape*
//! (matrix side, density) and a reducer-memory budget it enumerates
//! every valid `(block_side / m, ρ)` pair — the space Theorems 3.1–3.3
//! validate — prices each candidate with the cost-model simulator on a
//! [`ClusterProfile`], and returns the predicted-argmin plan together
//! with the full tradeoff table (Figures 3/6 as data).
//!
//! Two context knobs decide the winner:
//!
//! * **Reducer memory** (`memory_budget`, words) bounds the subproblem
//!   size: dense plans need `3m` words per reducer, sparse plans
//!   `≈ m` words once the `δ_M` density bound is folded in.
//! * **Aggregate cluster memory** ([`ClusterProfile::agg_mem_bytes`])
//!   bounds the per-round working set `≈ shuffle words`: a
//!   memory-constrained context cannot hold the monolithic `3qn`-word
//!   round in flight and is forced to `ρ < q` — the mechanical form of
//!   the paper's context dependence (checked by `BENCH_planner.json`).

use anyhow::{bail, Result};

/// Candidates needing more rounds than this are pruned from the
/// enumeration (not silently mis-priced): at `round_setup` seconds of
/// fixed cost per round, a plan with thousands of rounds is never
/// competitive, and pricing a million-round candidate per search would
/// make `m3 plan` O(q) per ρ for no decision value.
pub const MAX_PLAN_ROUNDS: usize = 4096;

use crate::matrix::gen::er_output_density;
use crate::simulator::{
    simulate_dense2d, simulate_dense2d_schedule, simulate_dense3d, simulate_sparse3d,
    simulate_strassen, volumes_strassen, ClusterProfile, SimResult,
};

use super::planner::{Plan2d, Plan3d, SparsePlan};

/// The knobs of one candidate plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanDesc {
    /// 3D dense: `(block_side, ρ)` with `q = side/block_side`.
    Dense3d {
        /// Matrix side `√n`.
        side: usize,
        /// Block side `√m`.
        block_side: usize,
        /// Replication factor ρ.
        rho: usize,
    },
    /// 2D dense: `(m, ρ)` with `s = n/m` strips.
    Dense2d {
        /// Matrix side `√n`.
        side: usize,
        /// Subproblem size `m` in words.
        m: usize,
        /// Replication factor ρ.
        rho: usize,
    },
    /// 3D sparse: `(block_side, ρ)` over an Erdős–Rényi input.
    Sparse3d {
        /// Matrix side `√n`.
        side: usize,
        /// Sparse block side `√m'`.
        block_side: usize,
        /// Replication factor ρ.
        rho: usize,
    },
    /// Blocked-Strassen schedule: `levels ≥ 1` recursion levels as
    /// round phases over unit blocks of side `side / 2^levels`
    /// (`levels = 0` *is* the classical grid, listed as `Dense3d`).
    Strassen {
        /// Matrix side `√n`.
        side: usize,
        /// Recursion levels `L`.
        levels: usize,
    },
}

impl PlanDesc {
    /// The candidate's replication factor (1 for Strassen schedules:
    /// each level's groups run one phase per round).
    pub fn rho(&self) -> usize {
        match *self {
            PlanDesc::Dense3d { rho, .. }
            | PlanDesc::Dense2d { rho, .. }
            | PlanDesc::Sparse3d { rho, .. } => rho,
            PlanDesc::Strassen { .. } => 1,
        }
    }

    /// Blocks/strips per dimension (the ρ ≤ · bound): `q` for 3D plans,
    /// `s` for 2D, the unit-block grid side `2^L` for Strassen.
    pub fn q(&self) -> usize {
        match *self {
            PlanDesc::Dense3d {
                side, block_side, ..
            }
            | PlanDesc::Sparse3d {
                side, block_side, ..
            } => side / block_side,
            PlanDesc::Dense2d { side, m, .. } => side * side / m,
            PlanDesc::Strassen { levels, .. } => 1 << levels,
        }
    }

    /// Is this the monolithic (minimum-round) plan for its block size?
    pub fn is_monolithic(&self) -> bool {
        self.rho() == self.q()
    }

    /// Short human-readable label.
    pub fn label(&self) -> String {
        match *self {
            PlanDesc::Dense3d {
                side,
                block_side,
                rho,
            } => format!("3d n={side} b={block_side} rho={rho}"),
            PlanDesc::Dense2d { side, m, rho } => format!("2d n={side} m={m} rho={rho}"),
            PlanDesc::Sparse3d {
                side,
                block_side,
                rho,
            } => format!("sp n={side} b={block_side} rho={rho}"),
            PlanDesc::Strassen { side, levels } => format!("st n={side} L={levels}"),
        }
    }
}

/// One candidate plan with its predicted cost on the search profile.
#[derive(Debug, Clone)]
pub struct PricedPlan {
    /// The candidate's knobs.
    pub desc: PlanDesc,
    /// Round count.
    pub rounds: usize,
    /// Reducer-memory words the plan needs (≤ the search budget).
    pub reducer_words: f64,
    /// Per-round shuffle-size bound in words (the round working set).
    pub shuffle_words: f64,
    /// Whether the round working set fits the profile's aggregate
    /// memory. Infeasible candidates stay in the table (they are the
    /// context-dependence evidence) but are never chosen.
    pub feasible: bool,
    /// Predicted total seconds.
    pub total_secs: f64,
    /// Predicted communication seconds.
    pub comm_secs: f64,
    /// Predicted computation seconds.
    pub comp_secs: f64,
    /// Predicted infrastructure seconds.
    pub infra_secs: f64,
}

impl PricedPlan {
    fn from_sim(
        desc: PlanDesc,
        reducer_words: f64,
        shuffle_words: f64,
        sim: &SimResult,
        profile: &ClusterProfile,
    ) -> Self {
        PricedPlan {
            desc,
            rounds: sim.rounds.len(),
            reducer_words,
            shuffle_words,
            feasible: fits_cluster_memory(shuffle_words, profile),
            total_secs: sim.total(),
            comm_secs: sim.comm(),
            comp_secs: sim.comp(),
            infra_secs: sim.infra(),
        }
    }
}

/// Does a round with `shuffle_words` in flight fit the profile's
/// aggregate working memory?
pub fn fits_cluster_memory(shuffle_words: f64, profile: &ClusterProfile) -> bool {
    shuffle_words * profile.bytes_per_word <= profile.agg_mem_bytes()
}

/// A completed plan search: the full candidate table (deterministic
/// order: block size ascending, then ρ ascending) and the chosen index.
#[derive(Debug, Clone)]
pub struct PlanSearch {
    /// Every enumerated candidate, priced.
    pub candidates: Vec<PricedPlan>,
    /// Index of the predicted-argmin feasible candidate.
    pub chosen: usize,
}

impl PlanSearch {
    /// The chosen candidate.
    pub fn chosen(&self) -> &PricedPlan {
        &self.candidates[self.chosen]
    }

    /// Cheapest predicted total over all candidates (feasible or not).
    pub fn min_total_secs(&self) -> f64 {
        self.candidates
            .iter()
            .map(|c| c.total_secs)
            .fold(f64::INFINITY, f64::min)
    }

    /// Costliest predicted total over all candidates.
    pub fn max_total_secs(&self) -> f64 {
        self.candidates
            .iter()
            .map(|c| c.total_secs)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Pick the argmin feasible candidate (first wins ties, so the
    /// search is deterministic for a fixed enumeration order).
    fn pick(candidates: Vec<PricedPlan>) -> Result<Self> {
        let mut chosen: Option<usize> = None;
        for (i, c) in candidates.iter().enumerate() {
            if !c.feasible {
                continue;
            }
            let better = match chosen {
                None => true,
                Some(b) => c.total_secs < candidates[b].total_secs,
            };
            if better {
                chosen = Some(i);
            }
        }
        match chosen {
            Some(chosen) => Ok(PlanSearch { candidates, chosen }),
            None => bail!(
                "no feasible plan: {} candidates all exceed the cluster memory",
                candidates.len()
            ),
        }
    }
}

/// Divisors of `x` in increasing order.
fn divisors(x: usize) -> Vec<usize> {
    let mut small = vec![];
    let mut large = vec![];
    let mut d = 1;
    while d * d <= x {
        if x % d == 0 {
            small.push(d);
            if d != x / d {
                large.push(x / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Enumerate and price every valid 3D dense plan for `side` under a
/// reducer-memory budget of `memory_budget` words (`3m ≤ budget`),
/// returning the search table and the chosen plan.
pub fn plan_dense3d(
    side: usize,
    memory_budget: usize,
    profile: &ClusterProfile,
) -> Result<(Plan3d, PlanSearch)> {
    if side == 0 {
        bail!("side must be positive");
    }
    let mut candidates = vec![];
    for block_side in divisors(side) {
        if 3 * block_side * block_side > memory_budget {
            break; // divisors ascend; everything later is too big too
        }
        let q = side / block_side;
        for rho in divisors(q) {
            if q / rho + 1 > MAX_PLAN_ROUNDS {
                continue;
            }
            let plan = Plan3d::new(side, block_side, rho)?;
            candidates.push(PricedPlan::from_sim(
                PlanDesc::Dense3d {
                    side,
                    block_side,
                    rho,
                },
                plan.reducer_words_bound() as f64,
                plan.shuffle_words_bound() as f64,
                &simulate_dense3d(&plan, profile),
                profile,
            ));
        }
    }
    if candidates.is_empty() {
        bail!("no valid 3D plan for side {side} under a {memory_budget}-word reducer budget");
    }
    let search = PlanSearch::pick(candidates)?;
    let plan = match search.chosen().desc {
        PlanDesc::Dense3d {
            side,
            block_side,
            rho,
        } => Plan3d::new(side, block_side, rho)?,
        _ => unreachable!("dense-3D search yields dense-3D candidates"),
    };
    Ok((plan, search))
}

/// Enumerate and price every valid 2D dense plan (`m = side·h` with
/// `h | side`, `3m ≤ budget`, `ρ | s`).
pub fn plan_dense2d(
    side: usize,
    memory_budget: usize,
    profile: &ClusterProfile,
) -> Result<(Plan2d, PlanSearch)> {
    if side == 0 {
        bail!("side must be positive");
    }
    let mut candidates = vec![];
    for h in divisors(side) {
        let m = side * h;
        if 3 * m > memory_budget {
            break;
        }
        let s = side * side / m;
        for rho in divisors(s) {
            if s / rho > MAX_PLAN_ROUNDS {
                continue;
            }
            let plan = Plan2d::new(side, m, rho)?;
            candidates.push(PricedPlan::from_sim(
                PlanDesc::Dense2d { side, m, rho },
                plan.reducer_words_bound() as f64,
                plan.shuffle_words_bound() as f64,
                &simulate_dense2d(&plan, profile),
                profile,
            ));
        }
    }
    if candidates.is_empty() {
        bail!("no valid 2D plan for side {side} under a {memory_budget}-word reducer budget");
    }
    let search = PlanSearch::pick(candidates)?;
    let plan = match search.chosen().desc {
        PlanDesc::Dense2d { side, m, rho } => Plan2d::new(side, m, rho)?,
        _ => unreachable!("dense-2D search yields dense-2D candidates"),
    };
    Ok((plan, search))
}

/// Enumerate and price the full dense tradeoff space *including* the
/// blocked-Strassen schedules: every classical `(block_side, ρ)` pair
/// (exactly [`plan_dense3d`]'s table — those candidates *are* `L = 0`,
/// where [`super::strassen::AlgoStrassen`] degenerates to `Algo3d`)
/// plus one candidate per recursion depth `L ≥ 1` with `2^L | side`.
/// A Strassen reducer holds up to four signed operand blocks plus the
/// combination it builds, so its budget gate is `5·bs²` words with
/// `bs = side / 2^L`; its working-set gate is the *largest* per-round
/// shuffle of the schedule (the forward fan, `6·(7/4)^{L-1}·n` words),
/// which is what keeps deep recursions out of memory-starved contexts.
/// The chosen descriptor answers "how many sub-cubic levels does this
/// context afford?" — the new point on the paper's §1 tradeoff curve.
pub fn plan_strassen(
    side: usize,
    memory_budget: usize,
    profile: &ClusterProfile,
) -> Result<PlanSearch> {
    let (_, classical) = plan_dense3d(side, memory_budget, profile)?;
    let mut candidates = classical.candidates;
    let mut levels = 1usize;
    while levels < 32 && side % (1usize << levels) == 0 {
        let bs = side >> levels;
        if 5 * bs * bs <= memory_budget && 2 * levels + 1 <= MAX_PLAN_ROUNDS {
            let vols = volumes_strassen(side, levels);
            let shuffle = vols.iter().map(|v| v.shuffle_words).fold(0.0, f64::max);
            candidates.push(PricedPlan::from_sim(
                PlanDesc::Strassen { side, levels },
                (5 * bs * bs) as f64,
                shuffle,
                &simulate_strassen(side, levels, profile),
                profile,
            ));
        }
        levels += 1;
    }
    PlanSearch::pick(candidates)
}

/// Enumerate and price every valid 3D sparse plan for an Erdős–Rényi
/// input with `nnz_per_row` expected non-zeros per row. Block sides are
/// the divisors of `side` whose expected block population fits the
/// budget (`block² · δ_M ≤ budget`, the same sizing rule as
/// [`SparsePlan::from_memory_budget`] without the power-of-two snap).
pub fn plan_sparse3d(
    side: usize,
    nnz_per_row: usize,
    memory_budget: usize,
    profile: &ClusterProfile,
) -> Result<(SparsePlan, PlanSearch)> {
    if side == 0 {
        bail!("side must be positive");
    }
    let delta = nnz_per_row as f64 / side as f64;
    let delta_m = delta.max(er_output_density(side, delta));
    if delta_m <= 0.0 {
        bail!("density must be positive");
    }
    let mut candidates = vec![];
    for block_side in divisors(side) {
        if (block_side * block_side) as f64 * delta_m > memory_budget as f64 {
            break;
        }
        let q = side / block_side;
        for rho in divisors(q) {
            if q / rho + 1 > MAX_PLAN_ROUNDS {
                continue;
            }
            let plan = SparsePlan::new(side, block_side, rho, delta, delta_m)?;
            candidates.push(PricedPlan::from_sim(
                PlanDesc::Sparse3d {
                    side,
                    block_side,
                    rho,
                },
                plan.expected_reducer_words(),
                plan.expected_shuffle_words(),
                &simulate_sparse3d(&plan, profile),
                profile,
            ));
        }
    }
    if candidates.is_empty() {
        bail!(
            "no valid sparse plan for side {side} (k={nnz_per_row}) under a \
             {memory_budget}-word reducer budget"
        );
    }
    let search = PlanSearch::pick(candidates)?;
    let plan = match search.chosen().desc {
        PlanDesc::Sparse3d {
            side, block_side, ..
        } => SparsePlan::new(side, block_side, search.chosen().desc.rho(), delta, delta_m)?,
        _ => unreachable!("sparse search yields sparse candidates"),
    };
    Ok((plan, search))
}

/// Re-plan the *tail* of a 3D dense run: given the committed product
/// widths (`committed`, possibly empty) and the remaining group count,
/// pick the uniform tail width ρ' — a divisor of the remaining groups,
/// at least the last committed width, whose `3ρ'n`-word round working
/// set still fits the profile's aggregate memory — whose pending
/// rounds price cheapest on `profile`. Returns the winning tail widths
/// and the predicted seconds of the pending rounds (tail + final).
pub fn plan_dense3d_tail(
    side: usize,
    block_side: usize,
    committed: &[usize],
    profile: &ClusterProfile,
) -> Result<(Vec<usize>, f64)> {
    let q = side / block_side.max(1);
    let done: usize = committed.iter().sum();
    if done >= q {
        bail!("all {q} groups already committed");
    }
    let remaining = q - done;
    let n = (side * side) as f64;
    let floor = committed.last().copied().unwrap_or(1).max(1);
    let mut best: Option<(Vec<usize>, f64)> = None;
    for rho in divisors(remaining) {
        if rho < floor || remaining / rho + 1 > MAX_PLAN_ROUNDS {
            continue;
        }
        // The same feasibility gate as the spawn-time search: a widened
        // round must not put a working set in flight that the initial
        // plan search would have rejected for this cluster.
        if !fits_cluster_memory(3.0 * rho as f64 * n, profile) {
            continue;
        }
        let tail = vec![rho; remaining / rho];
        // Price only the pending rounds: a synthetic one-round prefix
        // of the last committed width reproduces the first tail
        // round's carry volume and read-chunk size exactly, without
        // re-pricing (and discarding) the whole committed prefix on
        // every candidate.
        let mut pricing = Vec::with_capacity(tail.len() + 1);
        if !committed.is_empty() {
            pricing.push(floor);
        }
        pricing.extend(tail.iter().copied());
        let sim =
            crate::simulator::simulate_dense3d_schedule(side, block_side, &pricing, profile);
        let skip = usize::from(!committed.is_empty());
        let pending: f64 = sim.per_round()[skip..].iter().sum();
        let better = match &best {
            None => true,
            Some((_, b)) => pending < *b,
        };
        if better {
            best = Some((tail, pending));
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!(
            "no tail width ≥ {floor} divides the remaining {remaining} groups"
        )
    })
}

/// Re-plan the *tail* of a 2D dense run. Unlike the 3D re-planner, 2D
/// rounds carry nothing — every round reads the static strips and
/// writes its own slice of the output — so the committed widths
/// constrain nothing: any positive widths covering the remaining
/// strips are legal, and the search may *narrow* as well as widen
/// (there is no 3D-style floor). Each uniform candidate ρ' must divide
/// the remaining strips and keep its `2ρ'n`-word round working set
/// inside the profile's aggregate memory. Returns the winning tail
/// widths and the predicted seconds of the pending rounds.
pub fn plan_dense2d_tail(
    side: usize,
    m: usize,
    committed: &[usize],
    profile: &ClusterProfile,
) -> Result<(Vec<usize>, f64)> {
    let s = side * side / m.max(1);
    let done: usize = committed.iter().sum();
    if done >= s {
        bail!("all {s} strips already committed");
    }
    let remaining = s - done;
    let n = (side * side) as f64;
    let mut best: Option<(Vec<usize>, f64)> = None;
    for rho in divisors(remaining) {
        if remaining / rho > MAX_PLAN_ROUNDS {
            continue;
        }
        if !fits_cluster_memory(2.0 * rho as f64 * n, profile) {
            continue;
        }
        let tail = vec![rho; remaining / rho];
        // 2D rounds are independent, so the pending rounds price
        // directly — no synthetic committed prefix is needed.
        let pending = simulate_dense2d_schedule(side, m, &tail, profile).total();
        let better = match &best {
            None => true,
            Some((_, b)) => pending < *b,
        };
        if better {
            best = Some((tail, pending));
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!("no feasible tail width for the remaining {remaining} strips")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_sorted_and_complete() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(divisors(7), vec![1, 7]);
    }

    #[test]
    fn dense3d_search_prices_every_valid_pair() {
        // side 16, budget 3·4² = 48: blocks {1, 2, 4}, ρ over divisors
        // of q ∈ {16, 8, 4} → 5 + 4 + 3 candidates.
        let p = ClusterProfile::inhouse();
        let (_, search) = plan_dense3d(16, 48, &p).unwrap();
        assert_eq!(search.candidates.len(), 12);
        for c in &search.candidates {
            assert!(c.total_secs > 0.0);
            assert!(c.reducer_words <= 48.0);
        }
    }

    #[test]
    fn chosen_plan_is_the_argmin() {
        let p = ClusterProfile::inhouse();
        let (_, search) = plan_dense3d(32000, 48_000_000, &p).unwrap();
        let best = search.chosen();
        for c in &search.candidates {
            assert!(
                best.total_secs <= c.total_secs,
                "{} ({:.0}s) beats chosen {} ({:.0}s)",
                c.desc.label(),
                c.total_secs,
                best.desc.label(),
                best.total_secs
            );
        }
    }

    #[test]
    fn unconstrained_inhouse_picks_the_monolithic_paper_plan() {
        // Paper Figures 2–3: biggest block the budget admits, ρ = q.
        let p = ClusterProfile::inhouse();
        let (plan, search) = plan_dense3d(32000, 48_000_000, &p).unwrap();
        assert_eq!(plan.block_side, 4000, "largest block under 3m ≤ 48e6");
        assert_eq!(plan.rho, plan.q(), "monolithic wins with memory to spare");
        assert!(search.chosen().desc.is_monolithic());
    }

    #[test]
    fn probed_flop_rate_flips_the_chosen_plan() {
        // Seeding the profile from the autotune probe must actually
        // change planning on a compute-bound shape. With bandwidth and
        // memory effectively infinite, total cost is flops/rate +
        // rounds·setup; the product flops are constant across
        // candidates (2·side³), so the rate only weighs the final sum
        // round's ρ·n flops against saved rounds. A scalar-era rate
        // makes the extra accumulators expensive (low ρ, more rounds);
        // a SIMD-class rate makes rounds the scarce resource (high ρ,
        // fewer rounds) — exactly the staleness bug this guards.
        let base = ClusterProfile {
            name: "compute-bound",
            nodes: 1,
            slots_per_node: 1,
            flops_per_node: 1.0,
            disk_bw: 1.0e18,
            net_bw: 1.0e18,
            round_setup: 1.0,
            small_chunk_coeff: 0.0,
            chunk_ref_bytes: 1.0,
            bytes_per_word: 8.0,
            spill_factor: 0.0,
            mem_per_node_bytes: 1.0e18,
            wire_bytes_per_word: 0.0,
            shuffle_bytes_per_sec: 0.0,
        };
        let scalar = base.with_probed_flops(2_700.0);
        let simd = base.with_probed_flops(400_000.0);
        let (p_scalar, _) = plan_dense3d(64, 768, &scalar).unwrap();
        let (p_simd, _) = plan_dense3d(64, 768, &simd).unwrap();
        assert_eq!((p_scalar.block_side, p_scalar.rho), (16, 2));
        assert_eq!((p_simd.block_side, p_simd.rho), (16, 4));
        assert!(
            p_simd.rounds() < p_scalar.rounds(),
            "faster measured kernels must buy fewer rounds"
        );
    }

    #[test]
    fn memory_constrained_context_forces_multi_round() {
        // Shrink the cluster memory until the 3qn-word monolithic round
        // cannot be in flight: the planner must fall back to ρ < q —
        // the paper's context-dependence, mechanically.
        let constrained = ClusterProfile::inhouse().with_mem_per_node(4.0e9);
        let (plan, search) = plan_dense3d(32000, 48_000_000, &constrained).unwrap();
        assert!(
            plan.rho < plan.q(),
            "constrained context must pick rho {} < q {}",
            plan.rho,
            plan.q()
        );
        assert!(search.chosen().feasible);
        // The monolithic candidate is still enumerated, marked
        // infeasible — the table is the evidence.
        let mono = search
            .candidates
            .iter()
            .find(|c| c.desc == PlanDesc::Dense3d { side: 32000, block_side: 4000, rho: 8 })
            .expect("monolithic candidate stays in the table");
        assert!(!mono.feasible);
    }

    #[test]
    fn dense2d_search_works() {
        let p = ClusterProfile::inhouse();
        let (plan, search) = plan_dense2d(16, 768, &p).unwrap();
        assert!(plan.m <= 256);
        assert!(!search.candidates.is_empty());
        assert!(search.chosen().feasible);
    }

    #[test]
    fn sparse_search_respects_density_budget() {
        let p = ClusterProfile::inhouse();
        let side = 1 << 20;
        let (plan, search) = plan_sparse3d(side, 8, 48_000_000, &p).unwrap();
        let delta_m = plan.delta_m;
        for c in &search.candidates {
            if let PlanDesc::Sparse3d { block_side, .. } = c.desc {
                assert!((block_side * block_side) as f64 * delta_m <= 48_000_000.0);
            }
        }
        // Q6: the sparse planner reaches block sides far beyond the
        // dense 4000 limit at the same budget.
        assert!(plan.block_side > 4000);
    }

    #[test]
    fn budget_too_small_errors() {
        let p = ClusterProfile::inhouse();
        assert!(plan_dense3d(16, 2, &p).is_err());
        assert!(plan_dense2d(16, 2, &p).is_err());
    }

    #[test]
    fn tail_replan_prefers_widest_remaining_width() {
        // After two committed ρ=1 rounds of q=8, the in-house profile
        // (memory to spare) widens the tail to one ρ=6 round.
        let p = ClusterProfile::inhouse();
        let (tail, secs) = plan_dense3d_tail(32000, 4000, &[1, 1], &p).unwrap();
        assert_eq!(tail, vec![6]);
        assert!(secs > 0.0);
        // With nothing committed the tail is the full monolithic plan.
        let (tail, _) = plan_dense3d_tail(32000, 4000, &[], &p).unwrap();
        assert_eq!(tail, vec![8]);
        // A fully committed run has nothing to re-plan.
        assert!(plan_dense3d_tail(32000, 4000, &[8], &p).is_err());
    }

    #[test]
    fn strassen_candidates_enumerated_alongside_classical() {
        // side 16, generous budget: the classical table (5+4+3+2+1 = 15
        // pairs over blocks {1,2,4,8,16}) plus one Strassen candidate
        // per level L ∈ {1,2,3,4} (2^L | 16) → 19 candidates, and the
        // Strassen rows carry the 5·bs² reducer bound and 2L+1 rounds.
        let p = ClusterProfile::inhouse();
        let search = plan_strassen(16, 5000, &p).unwrap();
        assert_eq!(search.candidates.len(), 19);
        let strassen: Vec<_> = search
            .candidates
            .iter()
            .filter(|c| matches!(c.desc, PlanDesc::Strassen { .. }))
            .collect();
        assert_eq!(strassen.len(), 4);
        for c in &strassen {
            let PlanDesc::Strassen { side, levels } = c.desc else {
                unreachable!()
            };
            assert_eq!(side, 16);
            assert_eq!(c.rounds, 2 * levels + 1);
            let bs = side >> levels;
            assert_eq!(c.reducer_words, (5 * bs * bs) as f64);
            assert_eq!(c.desc.rho(), 1);
            assert_eq!(c.desc.q(), 1 << levels);
        }
    }

    #[test]
    fn compute_rich_context_picks_sub_cubic_at_large_sides() {
        // On the compute-rich profile the local-multiply term dominates
        // at scale: saving 1/8 of the block products per level beats
        // the extra shuffle fan. At √n = 65536 the argmin is a Strassen
        // schedule; at √n = 8192 the per-level saving (≈1.2 s) is
        // smaller than one extra round's setup + fan, so the classical
        // grid keeps winning — the crossover is side-dependent.
        let p = ClusterProfile::compute_rich();
        let large = plan_strassen(65536, 6_000_000_000, &p).unwrap();
        assert!(
            matches!(large.chosen().desc, PlanDesc::Strassen { levels, .. } if levels >= 1),
            "compute-rich at 65536 chose {}",
            large.chosen().desc.label()
        );
        let small = plan_strassen(8192, 6_000_000_000, &p).unwrap();
        assert!(
            matches!(small.chosen().desc, PlanDesc::Dense3d { .. }),
            "compute-rich at 8192 chose {}",
            small.chosen().desc.label()
        );
    }

    #[test]
    fn shuffle_starved_context_stays_classical() {
        // Same shape, starved fabric: Strassen's signed-combination fan
        // (12.5n shuffled words at L = 1 vs the monolithic grid's 6n)
        // prices worse than the flops it saves, so the argmin stays
        // L = 0 even though the L = 1 candidate is feasible and priced.
        let p = ClusterProfile::shuffle_starved();
        let search = plan_strassen(65536, 6_000_000_000, &p).unwrap();
        assert!(
            matches!(search.chosen().desc, PlanDesc::Dense3d { .. }),
            "shuffle-starved chose {}",
            search.chosen().desc.label()
        );
        let l1 = search
            .candidates
            .iter()
            .find(|c| c.desc == PlanDesc::Strassen { side: 65536, levels: 1 })
            .expect("the L=1 candidate stays in the table");
        assert!(l1.feasible, "L=1 fits this cluster's memory — it loses on price");
        assert!(l1.total_secs > search.chosen().total_secs);
    }

    #[test]
    fn dense2d_tail_replan_may_narrow_and_widen() {
        // √n = 32000, m = 4000² → s = 64 strips. With memory to spare
        // the re-planner widens the pending tail to the biggest feasible
        // divisor; on a constrained cluster it may *narrow* below the
        // committed width — legal precisely because 2D rounds carry
        // nothing (the 3D re-planner's floor does not apply).
        let m = 4000 * 4000;
        let p = ClusterProfile::inhouse();
        let (tail, secs) = plan_dense2d_tail(32000, m, &[2, 2], &p).unwrap();
        assert_eq!(tail, vec![20, 20, 20], "widest feasible divisor of 60");
        assert!(secs > 0.0);
        let constrained = ClusterProfile::inhouse().with_mem_per_node(4.0e9);
        let (tail, _) = plan_dense2d_tail(32000, m, &[16], &constrained).unwrap();
        assert_eq!(tail, vec![3; 16]);
        assert!(tail[0] < 16, "narrower than the committed width");
        // Starved: not even ρ' = 1 fits; fully committed: nothing left.
        let starved = ClusterProfile::inhouse().with_mem_per_node(1.0e3);
        assert!(plan_dense2d_tail(32000, m, &[2], &starved).is_err());
        assert!(plan_dense2d_tail(32000, m, &[64], &p).is_err());
    }

    #[test]
    fn tail_replan_respects_cluster_memory() {
        // On the starved context (ρ ≤ 2 fits), the re-planner must not
        // widen past what the spawn-time search would admit: the best
        // memory-feasible tail after two ρ=2 rounds of q=8 is [2, 2],
        // never [4] — and if even the floor width no longer fits, the
        // re-plan fails instead of installing an infeasible round.
        let constrained = ClusterProfile::inhouse().with_mem_per_node(4.0e9);
        let (tail, _) = plan_dense3d_tail(32000, 4000, &[2, 2], &constrained).unwrap();
        assert_eq!(tail, vec![2, 2], "widening to [4] would exceed aggregate memory");
        let starved = ClusterProfile::inhouse().with_mem_per_node(1.0e3);
        assert!(plan_dense3d_tail(32000, 4000, &[2, 2], &starved).is_err());
    }
}
