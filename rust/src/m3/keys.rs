//! Key types for the M3 algorithms.
//!
//! The paper stores matrices as pairs keyed by block coordinates with a
//! `-1` dummy slot: `⟨(i,-1,j); A_{i,j}⟩` for 3D, `⟨(i,-1); A_i⟩` for
//! 2D. Reducer keys are full triplets `(i,h,j)` / pairs `(i,j)`.

/// 3D key `(i, h, j)`; `h = -1` marks input/output pairs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TripleKey {
    /// Output block row.
    pub i: i32,
    /// Inner block index (or -1 for input/output pairs).
    pub h: i32,
    /// Output block column.
    pub j: i32,
}

impl TripleKey {
    /// A reducer key `(i, h, j)`.
    pub fn new(i: usize, h: usize, j: usize) -> Self {
        Self {
            i: i as i32,
            h: h as i32,
            j: j as i32,
        }
    }

    /// An input/output key `(i, -1, j)`.
    pub fn io(i: usize, j: usize) -> Self {
        Self {
            i: i as i32,
            h: -1,
            j: j as i32,
        }
    }

    /// A carry key `(i, ℓ, j)` for partial sum `C^ℓ`.
    pub fn carry(i: usize, l: usize, j: usize) -> Self {
        Self::new(i, l, j)
    }

    /// True for `(i, -1, j)` input/output keys.
    pub fn is_io(&self) -> bool {
        self.h == -1
    }
}

/// 2D key `(i, j)`; `-1` marks input pairs (`(i,-1)` for `A_i`,
/// `(-1,j)` for `B_j`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PairKey {
    /// Output strip row (or -1 for B inputs).
    pub i: i32,
    /// Output strip column (or -1 for A inputs).
    pub j: i32,
}

impl PairKey {
    /// A reducer/output key `(i, j)`.
    pub fn new(i: usize, j: usize) -> Self {
        Self {
            i: i as i32,
            j: j as i32,
        }
    }

    /// The input key of `A_i`: `(i, -1)`.
    pub fn a_input(i: usize) -> Self {
        Self { i: i as i32, j: -1 }
    }

    /// The input key of `B_j`: `(-1, j)`.
    pub fn b_input(j: usize) -> Self {
        Self { i: -1, j: j as i32 }
    }
}

// Key wire codecs: fixed-width little-endian `i32` tuples (keys carry
// `-1` sentinels, so varints would cost 5 bytes per component).
impl crate::mapreduce::wire::Wire for TripleKey {
    fn wire_encode(&self, out: &mut Vec<u8>) {
        use crate::mapreduce::wire::put_i32;
        put_i32(out, self.i);
        put_i32(out, self.h);
        put_i32(out, self.j);
    }

    fn wire_decode(
        r: &mut crate::mapreduce::wire::ByteReader<'_>,
    ) -> Result<Self, crate::mapreduce::wire::WireError> {
        Ok(Self {
            i: r.i32()?,
            h: r.i32()?,
            j: r.i32()?,
        })
    }
}

impl crate::mapreduce::wire::Wire for PairKey {
    fn wire_encode(&self, out: &mut Vec<u8>) {
        use crate::mapreduce::wire::put_i32;
        put_i32(out, self.i);
        put_i32(out, self.j);
    }

    fn wire_decode(
        r: &mut crate::mapreduce::wire::ByteReader<'_>,
    ) -> Result<Self, crate::mapreduce::wire::WireError> {
        Ok(Self {
            i: r.i32()?,
            j: r.i32()?,
        })
    }
}

/// Euclidean (always non-negative) modulo for index arithmetic with
/// subtractions, e.g. `(k - i - ℓ - rρ) mod q`.
#[inline]
pub fn umod(x: isize, q: usize) -> usize {
    let q = q as isize;
    (((x % q) + q) % q) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_key_constructors() {
        let k = TripleKey::new(1, 2, 3);
        assert_eq!((k.i, k.h, k.j), (1, 2, 3));
        assert!(!k.is_io());
        let io = TripleKey::io(4, 5);
        assert_eq!((io.i, io.h, io.j), (4, -1, 5));
        assert!(io.is_io());
    }

    #[test]
    fn pair_key_constructors() {
        assert_eq!(PairKey::a_input(3), PairKey { i: 3, j: -1 });
        assert_eq!(PairKey::b_input(7), PairKey { i: -1, j: 7 });
        assert_eq!(PairKey::new(1, 2), PairKey { i: 1, j: 2 });
    }

    #[test]
    fn keys_order_deterministically() {
        let mut ks = vec![
            TripleKey::new(1, 0, 0),
            TripleKey::io(0, 0),
            TripleKey::new(0, 1, 0),
        ];
        ks.sort();
        assert_eq!(ks[0], TripleKey::io(0, 0)); // h=-1 sorts first within i=0
        assert_eq!(ks[2], TripleKey::new(1, 0, 0));
    }

    #[test]
    fn key_wire_roundtrips_including_sentinels() {
        use crate::mapreduce::wire::{ByteReader, Wire};
        for k in [TripleKey::new(0, 0, 0), TripleKey::io(7, 3), TripleKey::new(9, 2, 1)] {
            let mut buf = vec![];
            k.wire_encode(&mut buf);
            assert_eq!(buf.len(), 12);
            assert_eq!(k, TripleKey::wire_decode(&mut ByteReader::new(&buf)).unwrap());
        }
        for k in [PairKey::new(1, 2), PairKey::a_input(5), PairKey::b_input(0)] {
            let mut buf = vec![];
            k.wire_encode(&mut buf);
            assert_eq!(buf.len(), 8);
            assert_eq!(k, PairKey::wire_decode(&mut ByteReader::new(&buf)).unwrap());
        }
        assert!(TripleKey::wire_decode(&mut ByteReader::new(&[0; 11])).is_err());
        assert!(PairKey::wire_decode(&mut ByteReader::new(&[0; 7])).is_err());
    }

    #[test]
    fn umod_handles_negatives() {
        assert_eq!(umod(-1, 5), 4);
        assert_eq!(umod(-5, 5), 0);
        assert_eq!(umod(-13, 5), 2);
        assert_eq!(umod(7, 5), 2);
        assert_eq!(umod(0, 5), 0);
    }
}
