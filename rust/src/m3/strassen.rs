//! Blocked-Strassen MapReduce schedule: a sub-cubic round/work
//! tradeoff point.
//!
//! Every other algorithm in this crate pays the full cubic count of
//! base block products (`q³` for the 3D schedule). Strassen's identity
//! trades 8 quadrant products for 7 products plus 18 block
//! additions — applied `L` times blockwise, one classical multiply of
//! `8^L` unit-block products becomes `7^L` products at the price of
//! extra rounds and shuffle. This module expresses those levels as
//! MapReduce *round phases* on the existing engine:
//!
//! ```text
//! round r ∈ [0, L)      forward: split each operand pair into the 7
//!                       Strassen linear combinations T_t / S_t
//!                       (reduce-side axpby, signs exact: α,β ∈ {±1})
//! round L               base case: 7^L independent block products
//!                       through the accelerated LocalMultiply backend
//! round L+c, c ∈ [1,L]  combine: merge each group of 7 products into
//!                       the parent's 2×2 output quadrants
//! ```
//!
//! `2L+1` rounds total. Keys are `(path, role, pos)` packed into
//! [`TripleKey`] — `path` is the base-7 index of the product
//! sub-problem, `role` distinguishes A-side (0) / B-side (1) operands
//! and products (2), `pos` is the row-major unit-block position inside
//! the sub-problem. Values ride in [`DenseBlock`]; within this module
//! the variant encodes the *sign* of a shuffled contribution
//! (`A` = `+`, `B` = `−`) on reducer inputs and the operand *role* on
//! reducer outputs — rewrapping an `Arc` payload into another variant
//! is a pointer bump, so sign/role routing never copies a matrix.
//!
//! At `L = 0` the schedule degenerates to the classical dense 3D
//! algorithm and this type delegates verbatim to [`Algo3d`], so the
//! planner can treat `L` as one more axis of the `(block, ρ)` search.
//!
//! Numerical note: Strassen is *not* bit-identical to classical GEMM
//! on floats (the additions perturb rounding). On integer-valued
//! inputs every intermediate stays exactly representable, so the
//! equivalence suite pins bit-exactness there; float workloads verify
//! through the `--tol` relative-tolerance mode.

use std::sync::Arc;

use anyhow::Result;

use crate::mapreduce::types::Partitioner;
use crate::mapreduce::{Driver, JobMetrics, Mapper, MultiRoundAlgorithm, Pair, Reducer};
use crate::matrix::{BlockGrid, DenseMatrix};
use crate::runtime::{kernels, LocalMultiply};

use super::algo3d::{Algo3d, BlockOps, Geometry};
use super::keys::TripleKey;
use super::multiply::{
    dense_3d_assemble, dense_3d_static_input, make_partitioner_3d, unshare, DenseBlock, DenseOps,
    M3Config,
};
use super::partitioner::StrassenPartitioner;
use super::planner::Plan3d;

// ---------------------------------------------------------------------
// The Strassen tables
// ---------------------------------------------------------------------

/// Signed contribution of an operand quadrant to a Strassen factor:
/// `(t, sign)` means quadrant feeds `T_t` (A-side) / `S_t` (B-side)
/// with coefficient `sign`.
type Term = (usize, f32);

/// A-side quadrants (row-major `A11 A12 A21 A22`) → factors
/// `T1..T7 = A11+A22, A21+A22, A11, A22, A11+A12, A21−A11, A12−A22`.
const A_TERMS: [&[Term]; 4] = [
    &[(0, 1.0), (2, 1.0), (4, 1.0), (5, -1.0)], // A11
    &[(4, 1.0), (6, 1.0)],                      // A12
    &[(1, 1.0), (5, 1.0)],                      // A21
    &[(0, 1.0), (1, 1.0), (3, 1.0), (6, -1.0)], // A22
];

/// B-side quadrants → factors
/// `S1..S7 = B11+B22, B11, B12−B22, B21−B11, B22, B11+B12, B21+B22`.
const B_TERMS: [&[Term]; 4] = [
    &[(0, 1.0), (1, 1.0), (3, -1.0), (5, 1.0)], // B11
    &[(2, 1.0), (5, 1.0)],                      // B12
    &[(3, 1.0), (6, 1.0)],                      // B21
    &[(0, 1.0), (2, -1.0), (4, 1.0), (6, 1.0)], // B22
];

/// Product `P_{t+1}` → signed output quadrants, per the post-additions
/// `C11 = P1+P4−P5+P7, C12 = P3+P5, C21 = P2+P4, C22 = P1−P2+P3+P6`.
/// Entries are `((qi, qj), sign)` with row-major quadrants.
const C_TERMS: [&[((usize, usize), f32)]; 7] = [
    &[((0, 0), 1.0), ((1, 1), 1.0)],  // P1 → C11, C22
    &[((1, 0), 1.0), ((1, 1), -1.0)], // P2 → C21, −C22
    &[((0, 1), 1.0), ((1, 1), 1.0)],  // P3 → C12, C22
    &[((0, 0), 1.0), ((1, 0), 1.0)],  // P4 → C11, C21
    &[((0, 0), -1.0), ((0, 1), 1.0)], // P5 → −C11, C12
    &[((1, 1), 1.0)],                 // P6 → C22
    &[((0, 0), 1.0)],                 // P7 → C11
];

/// Role constants for the key's `h` slot.
const ROLE_A: i32 = 0;
const ROLE_B: i32 = 1;
const ROLE_C: i32 = 2;

// ---------------------------------------------------------------------
// Map / reduce functions
// ---------------------------------------------------------------------

fn payload(v: &DenseBlock) -> &Arc<DenseMatrix> {
    match v {
        DenseBlock::A(m) | DenseBlock::B(m) | DenseBlock::C(m) => m,
    }
}

/// Rewrap a shared payload with a sign: `+` rides the `A` variant,
/// `−` the `B` variant (the reducer reads the sign back off the
/// variant). Pointer bump, never a copy.
fn signed(arc: &Arc<DenseMatrix>, sign: f32) -> DenseBlock {
    if sign >= 0.0 {
        DenseBlock::A(arc.clone())
    } else {
        DenseBlock::B(arc.clone())
    }
}

/// Rewrap a shared payload by operand role (A-side / B-side / product).
fn by_role(arc: &Arc<DenseMatrix>, role: i32) -> DenseBlock {
    match role {
        ROLE_A => DenseBlock::A(arc.clone()),
        ROLE_B => DenseBlock::B(arc.clone()),
        _ => DenseBlock::C(arc.clone()),
    }
}

/// Combine a group of signed contributions (variant `A` = `+`,
/// `B` = `−`) into one matrix: unshare the first positive (copy-free
/// when unique), `add_assign` further positives, `axpby(−1, x, 1, y)`
/// negatives — exact sign flips in IEEE arithmetic. Every Strassen
/// linear combination has at least one positive term, so the seed
/// always exists.
fn combine_signed(values: Vec<DenseBlock>) -> DenseMatrix {
    let mut acc: Option<DenseMatrix> = None;
    let mut pending_neg: Vec<Arc<DenseMatrix>> = Vec::new();
    for v in values {
        match v {
            DenseBlock::A(m) => match &mut acc {
                None => acc = Some(unshare(m)),
                Some(y) => y.add_assign(&m),
            },
            DenseBlock::B(m) => pending_neg.push(m),
            DenseBlock::C(_) => panic!("signed combination over a C block"),
        }
    }
    let mut acc = acc.expect("combination with no positive term");
    for m in pending_neg {
        kernels::axpby(-1.0, m.as_slice(), 1.0, acc.as_mut_slice());
    }
    acc
}

/// One mapper for all `2L+1` rounds; the round index picks the phase.
struct StrassenMapper {
    levels: usize,
}

impl Mapper<TripleKey, DenseBlock> for StrassenMapper {
    fn map(
        &self,
        round: usize,
        key: &TripleKey,
        value: &DenseBlock,
        emit: &mut dyn FnMut(TripleKey, DenseBlock),
    ) {
        let l = self.levels;
        let arc = payload(value);
        let (path, role, pos) = (key.i as usize, key.h, key.j as usize);
        if round < l {
            // Forward: split the round-r operand grid (side `g`) into
            // quadrants and shuffle each unit block to the factors its
            // quadrant feeds, signed.
            let g = 1usize << (l - round);
            let half = g / 2;
            let (li, lj) = (pos / g, pos % g);
            let quadrant = (li / half) * 2 + (lj / half);
            let sub = (li % half) * half + (lj % half);
            let terms = match role {
                ROLE_A => A_TERMS[quadrant],
                _ => B_TERMS[quadrant],
            };
            for &(t, sign) in terms {
                emit(
                    TripleKey::new(path * 7 + t, role as usize, sub),
                    signed(arc, sign),
                );
            }
        } else if round == l {
            // Base case: pair up each path's two operands under one
            // product key; the variant carries the role across the
            // shuffle.
            emit(TripleKey::new(path, ROLE_C as usize, 0), by_role(arc, role));
        } else {
            // Combine c = round − L: lift each product of child path
            // `parent·7 + t` into the parent's doubled output grid,
            // signed per the post-addition table.
            let c = round - l;
            let g = 1usize << (c - 1); // child output grid side
            let (parent, t) = (path / 7, path % 7);
            let (ci, cj) = (pos / g, pos % g);
            for &((qi, qj), sign) in C_TERMS[t] {
                let (oi, oj) = (qi * g + ci, qj * g + cj);
                emit(
                    TripleKey::new(parent, ROLE_C as usize, oi * 2 * g + oj),
                    signed(arc, sign),
                );
            }
        }
    }
}

/// One reducer for all rounds; the base case runs the block product
/// through the configured [`BlockOps`] (which records it in the pool's
/// block-product counter), everything else is signed axpby algebra.
struct StrassenReducer {
    levels: usize,
    ops: Arc<dyn BlockOps<DenseBlock>>,
}

impl Reducer<TripleKey, DenseBlock> for StrassenReducer {
    fn reduce(
        &self,
        round: usize,
        key: &TripleKey,
        values: Vec<DenseBlock>,
        emit: &mut dyn FnMut(TripleKey, DenseBlock),
    ) {
        let l = self.levels;
        if round < l {
            // Forward: resolve the ≤ 2 signed terms of T_t / S_t and
            // hand the factor onward under its operand role. A lone
            // positive term (T3 = A11 and friends) passes its shared
            // payload straight through without copying.
            let role = key.h;
            if values.len() == 1 {
                if let DenseBlock::A(m) = &values[0] {
                    let m = m.clone();
                    emit(*key, by_role(&m, role));
                    return;
                }
            }
            let m = Arc::new(combine_signed(values));
            emit(*key, by_role(&m, role));
        } else if round == l {
            // Base case P_t = T_t · S_t.
            let mut a = None;
            let mut b = None;
            for v in values {
                match v {
                    DenseBlock::A(m) => a = Some(DenseBlock::A(m)),
                    DenseBlock::B(m) => b = Some(DenseBlock::B(m)),
                    DenseBlock::C(_) => panic!("unexpected C block in base case"),
                }
            }
            let (a, b) = (
                a.expect("base case without A-side factor"),
                b.expect("base case without B-side factor"),
            );
            emit(*key, self.ops.fma(&a, &b, None));
        } else {
            // Combine: fold the signed product contributions of one
            // output position; the last combine round emits the final
            // `(i,−1,j)` unit blocks the assembler expects.
            let m = combine_signed(values);
            let out = if round == 2 * l {
                let g = 1usize << l;
                let pos = key.j as usize;
                TripleKey::io(pos / g, pos % g)
            } else {
                *key
            };
            emit(out, DenseBlock::c(m));
        }
    }
}

// ---------------------------------------------------------------------
// The algorithm
// ---------------------------------------------------------------------

enum Inner {
    /// `L = 0`: the classical dense 3D schedule, verbatim.
    Delegate { alg: Algo3d<DenseBlock> },
    /// `L ≥ 1`: the Strassen round phases.
    Recursion {
        levels: usize,
        mapper: StrassenMapper,
        reducer: StrassenReducer,
        partitioner: StrassenPartitioner,
    },
}

/// Blocked-Strassen multi-round algorithm (see the module docs for the
/// round structure). Construct with [`AlgoStrassen::new`]; run through
/// the ordinary [`Driver`], or use [`multiply_dense_strassen`] for the
/// packaged matrix-in / matrix-out path.
pub struct AlgoStrassen {
    side: usize,
    inner: Inner,
}

impl AlgoStrassen {
    /// Build the algorithm for `side × side` operands at recursion
    /// depth `levels`.
    ///
    /// `levels = 0` delegates to [`Algo3d`] under `cfg`'s
    /// `(block_side, ρ)` — bit-identical to `multiply_dense_3d`.
    /// `levels ≥ 1` requires `2^levels | side`; `cfg`'s block and ρ are
    /// ignored (the unit-block side is `side / 2^levels`).
    pub fn new(
        side: usize,
        levels: usize,
        cfg: &M3Config,
        ops: Arc<dyn BlockOps<DenseBlock>>,
    ) -> Result<Self> {
        let inner = if levels == 0 {
            let plan = Plan3d::new(side, cfg.block_side, cfg.rho)?;
            let geo: Geometry = plan.into();
            let partitioner = make_partitioner_3d(cfg.partitioner, geo.q, geo.rho);
            Inner::Delegate {
                alg: Algo3d::new(geo, ops, partitioner),
            }
        } else {
            anyhow::ensure!(
                side % (1 << levels) == 0 && side >> levels > 0,
                "side {side} is not divisible into 2^{levels} quadrant tiers"
            );
            Inner::Recursion {
                levels,
                mapper: StrassenMapper { levels },
                reducer: StrassenReducer { levels, ops },
                partitioner: StrassenPartitioner { levels },
            }
        };
        Ok(Self { side, inner })
    }

    /// Unit-block side: `side / 2^L` for the recursion, the classical
    /// block side for the `L = 0` delegate.
    pub fn unit_block_side(&self) -> usize {
        match &self.inner {
            Inner::Delegate { alg } => self.side / alg.schedule().q(),
            Inner::Recursion { levels, .. } => self.side >> levels,
        }
    }

    fn grid(&self) -> BlockGrid {
        BlockGrid::new(self.side, self.unit_block_side())
    }

    /// The static input pairs for two operands: `(0, role, i·2^L + j)`
    /// unit blocks for the recursion, the classical `(i,−1,j)` io
    /// pairs for the delegate.
    pub fn static_input(
        &self,
        a: &DenseMatrix,
        b: &DenseMatrix,
    ) -> Vec<Pair<TripleKey, DenseBlock>> {
        let grid = self.grid();
        match &self.inner {
            Inner::Delegate { .. } => dense_3d_static_input(&grid, a, b),
            Inner::Recursion { levels, .. } => {
                let g = 1usize << levels;
                let mut input = Vec::with_capacity(2 * g * g);
                for ((i, j), blk) in grid.split(a) {
                    input.push(Pair::new(
                        TripleKey::new(0, ROLE_A as usize, i * g + j),
                        DenseBlock::a(blk),
                    ));
                }
                for ((i, j), blk) in grid.split(b) {
                    input.push(Pair::new(
                        TripleKey::new(0, ROLE_B as usize, i * g + j),
                        DenseBlock::b(blk),
                    ));
                }
                input
            }
        }
    }

    /// Assemble the final-round `(i,−1,j)` blocks into the product.
    pub fn assemble(&self, output: Vec<Pair<TripleKey, DenseBlock>>) -> DenseMatrix {
        dense_3d_assemble(&self.grid(), output)
    }
}

impl MultiRoundAlgorithm for AlgoStrassen {
    type K = TripleKey;
    type V = DenseBlock;

    fn num_rounds(&self) -> usize {
        match &self.inner {
            Inner::Delegate { alg } => alg.num_rounds(),
            Inner::Recursion { levels, .. } => 2 * levels + 1,
        }
    }

    fn mapper(&self, round: usize) -> &dyn Mapper<TripleKey, DenseBlock> {
        match &self.inner {
            Inner::Delegate { alg } => alg.mapper(round),
            Inner::Recursion { mapper, .. } => mapper,
        }
    }

    fn reducer(&self, round: usize) -> &dyn Reducer<TripleKey, DenseBlock> {
        match &self.inner {
            Inner::Delegate { alg } => alg.reducer(round),
            Inner::Recursion { reducer, .. } => reducer,
        }
    }

    fn partitioner(&self, round: usize) -> &dyn Partitioner<TripleKey> {
        match &self.inner {
            Inner::Delegate { alg } => alg.partitioner(round),
            Inner::Recursion { partitioner, .. } => partitioner,
        }
    }

    fn reads_static_input(&self, round: usize) -> bool {
        match &self.inner {
            Inner::Delegate { alg } => alg.reads_static_input(round),
            // The operands are consumed whole by the first forward
            // split; later rounds live entirely off the carry.
            Inner::Recursion { .. } => round == 0,
        }
    }

    fn carries_output(&self) -> bool {
        true
    }

    fn codec(&self) -> Option<crate::mapreduce::wire::CodecHandle<TripleKey, DenseBlock>> {
        // Both phases ship DenseBlock payloads; the combine messages'
        // sign rides the A/B variant byte, which the block codec
        // preserves exactly.
        use super::algo3d::Block3d;
        DenseBlock::wire_codec()
    }

    fn groups_hint(&self, round: usize) -> Option<usize> {
        match &self.inner {
            Inner::Delegate { alg } => alg.groups_hint(round),
            Inner::Recursion { levels, .. } => {
                let l = *levels;
                Some(if round < l {
                    // 7^(r+1) factor pairs, each a (2^(L−r−1))² grid.
                    2 * 7usize.pow(round as u32 + 1) * (1usize << (2 * (l - round - 1)))
                } else if round == l {
                    7usize.pow(l as u32)
                } else {
                    let c = round - l;
                    7usize.pow((l - c) as u32) * (1usize << (2 * c))
                })
            }
        }
    }
}

/// Multiply two dense square matrices on the Strassen schedule at
/// recursion depth `levels` (`levels = 0` runs the classical 3D
/// algorithm under `cfg`, bit-identical to `multiply_dense_3d`).
pub fn multiply_dense_strassen(
    a: &DenseMatrix,
    b: &DenseMatrix,
    levels: usize,
    cfg: &M3Config,
    backend: Arc<dyn LocalMultiply>,
) -> Result<(DenseMatrix, JobMetrics)> {
    anyhow::ensure!(a.rows() == a.cols(), "A must be square");
    anyhow::ensure!(b.rows() == b.cols(), "B must be square");
    anyhow::ensure!(a.rows() == b.rows(), "A and B must have the same side");
    let alg = AlgoStrassen::new(a.rows(), levels, cfg, Arc::new(DenseOps::new(backend)))?;
    let input = alg.static_input(a, b);
    let mut driver = Driver::new(cfg.engine);
    driver.set_transport(cfg.transport.clone());
    let res = driver.run(&alg, &input);
    Ok((alg.assemble(res.output), res.metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::{EngineConfig, Pool, StepRun};
    use crate::matrix::gen;
    use crate::runtime::NaiveMultiply;
    use crate::util::rng::Xoshiro256ss;

    fn cfg(workers: usize) -> M3Config {
        let mut c = M3Config::new(4, 2);
        c.engine = EngineConfig {
            map_tasks: 5,
            reduce_tasks: 4,
            workers,
        };
        c
    }

    fn ops() -> Arc<dyn BlockOps<DenseBlock>> {
        Arc::new(DenseOps::new(Arc::new(NaiveMultiply)))
    }

    /// On integer-valued inputs every Strassen intermediate is exactly
    /// representable, so L ∈ {1, 2} must reproduce the classical
    /// product bit for bit at every worker count — and run exactly
    /// `7^L` base block products over `2L+1` rounds, with every
    /// round's reducer-group count matching the analytic hint.
    #[test]
    fn strassen_matches_the_classical_product_bit_for_bit_on_integer_inputs() {
        let side = 16usize;
        let mut rng = Xoshiro256ss::new(91);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let want = a.matmul_naive(&b);
        for levels in [1usize, 2] {
            for workers in [1usize, 2, 8] {
                let c = cfg(workers);
                let alg = AlgoStrassen::new(side, levels, &c, ops()).unwrap();
                let input = alg.static_input(&a, &b);
                let mut d = Driver::new(c.engine);
                let res = d.run(&alg, &input);
                let got = alg.assemble(res.output);
                let ctx = format!("L={levels} workers={workers}");
                assert_eq!(got.as_slice(), want.as_slice(), "{ctx}: product");
                assert_eq!(res.metrics.num_rounds(), 2 * levels + 1, "{ctx}: rounds");
                assert_eq!(
                    res.metrics.total_block_products(),
                    7usize.pow(levels as u32),
                    "{ctx}: base products"
                );
                for r in &res.metrics.rounds {
                    assert_eq!(
                        Some(r.num_reducers),
                        alg.groups_hint(r.round),
                        "{ctx}: groups hint r{}",
                        r.round
                    );
                }
            }
        }
    }

    /// `L = 0` must be the classical 3D schedule verbatim — identical
    /// output bits (on arbitrary float inputs), rounds, and block
    /// products.
    #[test]
    fn level_zero_degenerates_to_the_classical_3d_schedule() {
        use super::super::multiply::multiply_dense_3d;
        let side = 16usize;
        let mut rng = Xoshiro256ss::new(92);
        let a = gen::dense_uniform(side, side, &mut rng);
        let b = gen::dense_uniform(side, side, &mut rng);
        let c = cfg(4);
        let (want, want_m) = multiply_dense_3d(&a, &b, &c, Arc::new(NaiveMultiply)).unwrap();
        let (got, got_m) = multiply_dense_strassen(&a, &b, 0, &c, Arc::new(NaiveMultiply)).unwrap();
        assert_eq!(got.as_slice(), want.as_slice(), "L=0 must be bit-identical");
        assert_eq!(got_m.num_rounds(), want_m.num_rounds());
        assert_eq!(got_m.total_block_products(), want_m.total_block_products());
        assert_eq!(got_m.total_block_products(), 4 * 4 * 4, "q³ for q=4");
    }

    /// The acceptance-criteria ratio: one Strassen level performs 7
    /// base block products where the classical schedule on the same
    /// split performs 8 — asserted through the engine's round metrics.
    #[test]
    fn one_level_trades_8_block_products_for_7() {
        use super::super::multiply::multiply_dense_3d;
        let side = 16usize;
        let mut rng = Xoshiro256ss::new(93);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let mut classical = cfg(4);
        classical.block_side = side / 2; // q = 2: the same 2×2 split
        classical.rho = 1;
        let (want, m3d) = multiply_dense_3d(&a, &b, &classical, Arc::new(NaiveMultiply)).unwrap();
        let (got, ms) =
            multiply_dense_strassen(&a, &b, 1, &cfg(4), Arc::new(NaiveMultiply)).unwrap();
        assert_eq!(m3d.total_block_products(), 8);
        assert_eq!(ms.total_block_products(), 7);
        assert_eq!(got.as_slice(), want.as_slice(), "integer inputs stay exact");
    }

    /// Preemption carry: discarding any round's attempt and re-running
    /// it must leave the final product bit-identical — the carried
    /// intermediate factors/products tolerate re-execution.
    #[test]
    fn strassen_survives_preemption_at_every_round() {
        let side = 16usize;
        let levels = 2usize;
        let mut rng = Xoshiro256ss::new(94);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let want = a.matmul_naive(&b);
        let c = cfg(4);
        for discard_at in 0..(2 * levels + 1) {
            let alg = AlgoStrassen::new(side, levels, &c, ops()).unwrap();
            let input = alg.static_input(&a, &b);
            let mut step = StepRun::with_pool(c.engine, alg, input, Arc::new(Pool::new(4)));
            for _ in 0..discard_at {
                step.step_commit();
            }
            step.step_discard();
            assert_eq!(step.next_round(), discard_at, "discard must not advance");
            while !step.is_done() {
                step.step_commit();
            }
            let res = step.into_result();
            let alg = AlgoStrassen::new(side, levels, &c, ops()).unwrap();
            let got = alg.assemble(res.output);
            assert_eq!(got.as_slice(), want.as_slice(), "discard at round {discard_at}");
        }
    }

    /// A seeded injury schedule (node kill, transient failures, a
    /// straggler) must be invisible in the product: recovery replays
    /// exactly the work the fault destroyed.
    #[test]
    fn strassen_under_seeded_faults_matches_the_fault_free_product() {
        use crate::fault::{FaultContext, FaultPlan, FaultSpec, NodeSet, Phase};
        let side = 16usize;
        let levels = 2usize;
        let mut rng = Xoshiro256ss::new(95);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let want = a.matmul_naive(&b);
        let plan = FaultPlan::none()
            .with_kill(0, Phase::Map, 0)
            .with_transient(0, Phase::Reduce, 2, 2)
            .with_slow(1, Phase::Reduce, 1, 16.0)
            .with_transient(1, Phase::Map, 0, 1);
        for workers in [1usize, 2, 8] {
            let c = cfg(workers);
            let alg = AlgoStrassen::new(side, levels, &c, ops()).unwrap();
            let input = alg.static_input(&a, &b);
            let fctx = Arc::new(FaultContext::new(
                NodeSet::new(4, 60 + workers as u64),
                plan.clone(),
                FaultSpec::default(),
            ));
            let mut d = Driver::new(c.engine);
            d.set_faults(fctx.clone());
            let res = d.run(&alg, &input);
            let got = alg.assemble(res.output);
            let ctx = format!("faulted strassen workers={workers}");
            assert_eq!(got.as_slice(), want.as_slice(), "{ctx}");
            let s = fctx.stats();
            assert!(s.failures >= 3, "{ctx}: the round-0 injuries are guaranteed");
        }
    }

    /// The signed A/B variant routing must survive serialization: a
    /// Strassen run on the serialized in-proc transport (the default)
    /// reproduces the zero-copy reference bit for bit, and on float
    /// inputs too — the codec preserves f32 bits and variant bytes.
    #[test]
    fn strassen_on_the_serialized_transport_matches_zero_copy_bit_for_bit() {
        use crate::mapreduce::TransportSel;
        let side = 16usize;
        let mut rng = Xoshiro256ss::new(96);
        let a = gen::dense_uniform(side, side, &mut rng);
        let b = gen::dense_uniform(side, side, &mut rng);
        for levels in [1usize, 2] {
            let mut zc = cfg(4);
            zc.transport = TransportSel::ZeroCopy;
            let (want, wm) =
                multiply_dense_strassen(&a, &b, levels, &zc, Arc::new(NaiveMultiply)).unwrap();
            let (got, sm) =
                multiply_dense_strassen(&a, &b, levels, &cfg(4), Arc::new(NaiveMultiply))
                    .unwrap();
            assert_eq!(got.as_slice(), want.as_slice(), "L={levels}");
            assert_eq!(wm.total_shuffle_bytes(), 0);
            assert!(sm.total_shuffle_bytes() > 0, "L={levels}: bytes measured");
            assert_eq!(sm.total_shuffle_words(), wm.total_shuffle_words());
        }
    }

    /// Bad shapes are rejected up front.
    #[test]
    fn indivisible_sides_are_rejected() {
        let c = cfg(1);
        assert!(AlgoStrassen::new(12, 3, &c, ops()).is_err(), "12 % 8 ≠ 0");
        assert!(AlgoStrassen::new(16, 2, &c, ops()).is_ok());
    }
}
