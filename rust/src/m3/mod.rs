//! The M3 library: the paper's multi-round matrix-multiplication
//! algorithms on the MapReduce engine.
//!
//! * [`algo3d`] — the 3D decomposition (paper Algorithm 1), generic over
//!   dense/sparse block payloads; `R = √n/(ρ√m) + 1` rounds, shuffle
//!   size `3ρn`, reducer size `3m` (Theorem 3.1).
//! * [`dense2d`] — the 2D baseline (paper Algorithm 2); `R = n/(ρm)`
//!   rounds, shuffle size `2ρn`, reducer size `3m` (Theorem 3.3).
//! * [`partitioner`] — the naive `31²i + 31j + k` hash and the balanced
//!   partitioner (paper Algorithm 3, Figure 1).
//! * [`planner`] — parameter validation and the theorems' formulas.
//! * [`autoplan`] — the auto-planner: enumerate every valid
//!   `(block_side, ρ)` for a shape under a reducer-memory budget, price
//!   each on a cluster profile, pick the predicted argmin (the paper's
//!   "suitably setting the round number according to the execution
//!   context", §1).
//! * [`multiply`] — the high-level public API (`multiply_dense_3d`,
//!   `multiply_sparse_3d`, `multiply_dense_2d`).
//! * [`strassen`] — the blocked-Strassen schedule: `L` recursion
//!   levels as round phases, `7^L` base products instead of `8^L`
//!   (sub-cubic work) for `2L+1` rounds and extra addition shuffle —
//!   a tradeoff point [`autoplan`] prices against the classical grid.

pub mod algo3d;
pub mod autoplan;
pub mod dense2d;
pub mod keys;
pub mod multiply;
pub mod partitioner;
pub mod planner;
pub mod sparse_tools;
pub mod strassen;

pub use autoplan::{
    plan_dense2d, plan_dense2d_tail, plan_dense3d, plan_dense3d_tail, plan_sparse3d, plan_strassen,
    PlanDesc, PlanSearch, PricedPlan,
};
pub use keys::{PairKey, TripleKey};
pub use multiply::{
    multiply_dense_2d, multiply_dense_3d, multiply_dense_3d_sr, multiply_sparse_3d, M3Config,
    PartitionerKind,
};
pub use planner::{Plan2d, Plan3d, SparsePlan};
pub use strassen::{multiply_dense_strassen, AlgoStrassen};
