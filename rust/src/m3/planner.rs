//! Parameter validation and the round/shuffle/reducer-size formulas of
//! Theorems 3.1–3.3.
//!
//! The paper's tradeoff knobs: subproblem size `m` (each reducer
//! multiplies `√m × √m` blocks, memory `3m`) and replication factor `ρ`
//! (shuffle volume `3ρn` per round). Round counts:
//!
//! * 3D dense/sparse: `R = √n/(ρ√m) + 1 = q/ρ + 1` with `q = √(n/m)`;
//! * 2D dense: `R = n/(ρm) = s/ρ` with `s = n/m` strips.

use anyhow::{bail, Result};

/// Plan of a 3D execution (paper Algorithm 1 / Theorem 3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan3d {
    /// Matrix side `√n`.
    pub side: usize,
    /// Block side `√m`.
    pub block_side: usize,
    /// Replication factor `ρ`.
    pub rho: usize,
}

impl Plan3d {
    /// Validate and construct. Requirements (paper's simplifying
    /// assumptions): `√m | √n`, `1 ≤ ρ ≤ q`, `ρ | q`.
    pub fn new(side: usize, block_side: usize, rho: usize) -> Result<Self> {
        if block_side == 0 || side == 0 {
            bail!("side and block side must be positive");
        }
        if side % block_side != 0 {
            bail!("block side {block_side} must divide matrix side {side}");
        }
        let q = side / block_side;
        if rho == 0 || rho > q {
            bail!("replication rho={rho} must be in [1, q={q}]");
        }
        if q % rho != 0 {
            bail!("rho={rho} must divide q={q} for even round distribution");
        }
        Ok(Self {
            side,
            block_side,
            rho,
        })
    }

    /// The monolithic (two-round) plan: `ρ = q`.
    pub fn monolithic(side: usize, block_side: usize) -> Result<Self> {
        let q = side / block_side.max(1);
        Self::new(side, block_side, q)
    }

    /// Blocks per dimension `q = √(n/m)`.
    pub fn q(&self) -> usize {
        self.side / self.block_side
    }

    /// Input size `n` in words.
    pub fn n(&self) -> usize {
        self.side * self.side
    }

    /// Subproblem size `m` in words.
    pub fn m(&self) -> usize {
        self.block_side * self.block_side
    }

    /// Round count `R = q/ρ + 1`.
    pub fn rounds(&self) -> usize {
        self.q() / self.rho + 1
    }

    /// Theorem 3.1 shuffle-size bound per round, in words: `3ρn`.
    pub fn shuffle_words_bound(&self) -> usize {
        3 * self.rho * self.n()
    }

    /// Theorem 3.1 reducer-size bound in words: `3m`.
    pub fn reducer_words_bound(&self) -> usize {
        3 * self.m()
    }

    /// Total shuffled words over all rounds: exactly `3nq`, independent
    /// of ρ (paper Q1). Per round (matching the simulator's
    /// [`crate::simulator::volumes_dense3d`]): round 0 shuffles `2ρn`
    /// (A and B fan-out, no carried C yet), each later product round
    /// `3ρn`, and the final summation round `ρn` — summing to
    /// `2ρn + (q/ρ − 1)·3ρn + ρn = 3nq`.
    pub fn total_shuffle_words(&self) -> usize {
        3 * self.n() * self.q()
    }

    /// Sequential work per reducer, `Θ(m^{3/2})` elementary products.
    pub fn reducer_flops(&self) -> usize {
        2 * self.block_side.pow(3)
    }
}

/// Plan of a 2D execution (paper Algorithm 2 / Theorem 3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan2d {
    /// Matrix side `√n`.
    pub side: usize,
    /// Subproblem size `m` in words (`√n ≤ m ≤ n`).
    pub m: usize,
    /// Replication factor `ρ`.
    pub rho: usize,
}

impl Plan2d {
    /// Validate and construct. Requirements: `√n | m` (strip height
    /// `m/√n` integral), `m | n`, `1 ≤ ρ ≤ s`, `ρ | s` with `s = n/m`.
    pub fn new(side: usize, m: usize, rho: usize) -> Result<Self> {
        if side == 0 || m == 0 {
            bail!("side and m must be positive");
        }
        let n = side * side;
        if m < side || m > n {
            bail!("m={m} must be in [sqrt(n)={side}, n={n}]");
        }
        if m % side != 0 {
            bail!("strip height m/sqrt(n) must be integral (m={m}, side={side})");
        }
        if n % m != 0 {
            bail!("m={m} must divide n={n}");
        }
        let s = n / m;
        if rho == 0 || rho > s {
            bail!("rho={rho} must be in [1, s={s}]");
        }
        if s % rho != 0 {
            bail!("rho={rho} must divide s={s}");
        }
        Ok(Self { side, m, rho })
    }

    /// Number of strips `s = n/m` per input matrix.
    pub fn strips(&self) -> usize {
        self.side * self.side / self.m
    }

    /// Strip height `m/√n`.
    pub fn strip_height(&self) -> usize {
        self.m / self.side
    }

    /// Round count `R = n/(ρm) = s/ρ`.
    pub fn rounds(&self) -> usize {
        self.strips() / self.rho
    }

    /// Theorem 3.3 shuffle-size bound per round, in words: `2ρn`.
    pub fn shuffle_words_bound(&self) -> usize {
        2 * self.rho * self.side * self.side
    }

    /// Theorem 3.3 reducer-size bound in words: `3m`.
    pub fn reducer_words_bound(&self) -> usize {
        3 * self.m
    }

    /// Total shuffle over all rounds, `O(n²/m)` — asymptotically worse
    /// than 3D's `O(n·√(n/m))` (paper Q5 / Figure 6).
    pub fn total_shuffle_words(&self) -> usize {
        self.shuffle_words_bound() * self.rounds()
    }
}

/// Largest power of two `≤ x` (1 for `x = 0`).
fn prev_power_of_two(x: usize) -> usize {
    if x == 0 {
        1
    } else {
        1 << x.ilog2()
    }
}

/// Plan of a 3D sparse execution (paper §3.2 / Theorem 3.2).
#[derive(Debug, Clone, Copy)]
pub struct SparsePlan {
    /// Matrix side `√n` (can be huge — blocks are sparse).
    pub side: usize,
    /// Sparse block side `√m'` with `m' = m/δ_M`.
    pub block_side: usize,
    /// Replication factor ρ.
    pub rho: usize,
    /// Input density δ.
    pub delta: f64,
    /// Density bound `δ_M = max(δ, δ̃_O)` used to size blocks.
    pub delta_m: f64,
}

impl SparsePlan {
    /// Build a sparse plan from the memory budget `m` (words per
    /// reducer), input density `δ`, and an output-density estimate
    /// `δ̃_O` (for Erdős–Rényi inputs, `δ²√n`). The block side is the
    /// largest power of two with `block_side² · δ_M ≤ m`, clipped so it
    /// divides `side`.
    pub fn from_memory_budget(
        side: usize,
        m: usize,
        delta: f64,
        delta_out: f64,
        rho: usize,
    ) -> Result<Self> {
        let delta_m = delta.max(delta_out);
        if delta_m <= 0.0 {
            bail!("density must be positive");
        }
        // m' = m / delta_M; block side = largest power of two ≤ √m'.
        // (The old `next_power_of_two() / 2` halved √m' whenever it was
        // already an exact power of two — a 4× memory under-use and ~2×
        // the rounds the budget actually needs.)
        let m_prime = (m as f64 / delta_m).max(1.0);
        let mut block_side = prev_power_of_two(m_prime.sqrt() as usize);
        block_side = block_side.clamp(1, side);
        while block_side > 1 && side % block_side != 0 {
            block_side /= 2;
        }
        Self::new(side, block_side, rho, delta, delta_m)
    }

    /// Validate an explicit plan.
    pub fn new(
        side: usize,
        block_side: usize,
        rho: usize,
        delta: f64,
        delta_m: f64,
    ) -> Result<Self> {
        if side % block_side != 0 {
            bail!("block side {block_side} must divide side {side}");
        }
        let q = side / block_side;
        if rho == 0 || rho > q.max(1) {
            bail!("rho={rho} must be in [1, q={q}]");
        }
        if q > 0 && q % rho != 0 {
            bail!("rho={rho} must divide q={q}");
        }
        if !(0.0..=1.0).contains(&delta) || delta_m <= 0.0 {
            bail!("invalid densities delta={delta} delta_m={delta_m}");
        }
        Ok(Self {
            side,
            block_side,
            rho,
            delta,
            delta_m,
        })
    }

    /// Blocks per dimension.
    pub fn q(&self) -> usize {
        self.side / self.block_side
    }

    /// Round count `R = q/ρ + 1` (equals Theorem 3.2's
    /// `δ√n·√n/(ρ√m) + 1` after substituting `√m' = √(m/δ_M)`).
    pub fn rounds(&self) -> usize {
        self.q() / self.rho + 1
    }

    /// Expected words per reducer: `(2δ + δ_O)·m' ≈ 3m` (2 input blocks
    /// at density δ, one output accumulator at density δ_O).
    pub fn expected_reducer_words(&self) -> f64 {
        let m_prime = (self.block_side * self.block_side) as f64;
        (2.0 * self.delta + self.delta_m) * m_prime
    }

    /// Expected shuffle words per round: `3ρ·δ_M·n` (Theorem 3.2 form
    /// for general sparse inputs).
    pub fn expected_shuffle_words(&self) -> f64 {
        3.0 * self.rho as f64 * self.delta_m * (self.side as f64) * (self.side as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan3d_valid_formulas() {
        // √n=16000, √m=4000 → q=4; ρ=2 → R=3 (paper's shapes).
        let p = Plan3d::new(16000, 4000, 2).unwrap();
        assert_eq!(p.q(), 4);
        assert_eq!(p.rounds(), 3);
        assert_eq!(p.shuffle_words_bound(), 3 * 2 * 16000 * 16000);
        assert_eq!(p.reducer_words_bound(), 3 * 4000 * 4000);
    }

    #[test]
    fn plan3d_monolithic_is_two_rounds() {
        let p = Plan3d::monolithic(16000, 4000).unwrap();
        assert_eq!(p.rho, 4);
        assert_eq!(p.rounds(), 2);
    }

    #[test]
    fn plan3d_rho_one_max_rounds() {
        let p = Plan3d::new(32000, 4000, 1).unwrap();
        assert_eq!(p.rounds(), 9); // q=8 → 8 product rounds + 1 final
    }

    #[test]
    fn plan3d_rejects_bad_params() {
        assert!(Plan3d::new(16, 5, 1).is_err()); // 5 ∤ 16
        assert!(Plan3d::new(16, 4, 0).is_err()); // ρ = 0
        assert!(Plan3d::new(16, 4, 8).is_err()); // ρ > q
        assert!(Plan3d::new(24, 4, 4).is_err()); // 4 ∤ 6
        assert!(Plan3d::new(0, 4, 1).is_err());
    }

    #[test]
    fn plan3d_total_shuffle_independent_of_rho() {
        // Q1: total shuffled data is exactly 3nq for every ρ — round 0
        // carries no C (2ρn), later product rounds shuffle 3ρn, and the
        // final round's ρn closes the telescope.
        for rho in [1, 2, 4, 8] {
            let p = Plan3d::new(1024, 128, rho).unwrap();
            assert_eq!(p.total_shuffle_words(), 3 * p.n() * p.q(), "rho={rho}");
            // Cross-check against the explicit per-round sum.
            let product_rounds = p.q() / p.rho;
            let per_round_sum = 2 * p.rho * p.n()
                + (product_rounds - 1) * 3 * p.rho * p.n()
                + p.rho * p.n();
            assert_eq!(p.total_shuffle_words(), per_round_sum, "rho={rho}");
        }
    }

    #[test]
    fn plan2d_valid_formulas() {
        // side=16, m=64 → strips s=4, strip height 4; ρ=2 → R=2.
        let p = Plan2d::new(16, 64, 2).unwrap();
        assert_eq!(p.strips(), 4);
        assert_eq!(p.strip_height(), 4);
        assert_eq!(p.rounds(), 2);
        assert_eq!(p.shuffle_words_bound(), 2 * 2 * 256);
        assert_eq!(p.reducer_words_bound(), 192);
    }

    #[test]
    fn plan2d_rejects_bad_params() {
        assert!(Plan2d::new(16, 8, 1).is_err()); // m < √n
        assert!(Plan2d::new(16, 300, 1).is_err()); // n % m != 0
        assert!(Plan2d::new(16, 64, 3).is_err()); // 3 ∤ 4
        assert!(Plan2d::new(16, 64, 8).is_err()); // ρ > s
    }

    #[test]
    fn plan2d_total_shuffle_worse_than_3d() {
        // Q5: with the same n and m, 2D total shuffle O(n²/m) exceeds 3D
        // total shuffle O(n√(n/m)).
        let side = 1024;
        let block = 128;
        let m = block * block;
        let p3 = Plan3d::new(side, block, 1).unwrap();
        let p2 = Plan2d::new(side, m, 1).unwrap();
        assert!(p2.total_shuffle_words() > p3.total_shuffle_words());
    }

    #[test]
    fn sparse_plan_from_budget() {
        // Paper Q6: √n = 2^20, 8 nnz/row → δ = 2^-17, δ_O = 2^-14,
        // m ≈ dense 4000² → √m' = √(m/δ_M) = 512000, so the block side
        // must be exactly 2^18 (the largest power of two ≤ 512000) —
        // the old `2^17..=2^19` window asserted nothing sharper. √m'
        // is not an exact power of two here, so the halving bug itself
        // is pinned by `sparse_plan_budget_exact_power_of_two_not_halved`.
        let side = 1 << 20;
        let delta = 8.0 / side as f64;
        let delta_out = delta * delta * side as f64;
        let m = 4000 * 4000;
        let p = SparsePlan::from_memory_budget(side, m, delta, delta_out, 1).unwrap();
        assert_eq!(p.block_side, 1 << 18, "largest power of two ≤ √m'");
        // Expected reducer words stay within the 3m budget.
        let words = p.expected_reducer_words();
        assert!(words <= 3.0 * m as f64 * 1.1, "words={words}");
    }

    #[test]
    fn sparse_plan_budget_exact_power_of_two_not_halved() {
        // Regression for the headline bug: when √(m/δ_M) is an *exact*
        // power of two the budget admits that block side exactly, and
        // `from_memory_budget` must select it — the old code computed
        // `(√m').next_power_of_two() / 2`, halving it to 2^17, which
        // uses 4× less memory than budgeted and runs ~2× the rounds.
        let side = 1usize << 20;
        let delta_m = 2f64.powi(-14);
        let m = 1usize << 22; // m / δ_M = 2^36 → √m' = 2^18 exactly
        let p = SparsePlan::from_memory_budget(side, m, 2f64.powi(-17), delta_m, 1).unwrap();
        assert_eq!(p.block_side, 1 << 18, "exact power of two must not be halved");
        // The chosen block fills the budget exactly: block² · δ_M = m.
        let used = (p.block_side * p.block_side) as f64 * delta_m;
        assert_eq!(used, m as f64);
        // Round count at ρ=1 is q+1 with q = side/block = 4 — the buggy
        // half-size block would have doubled q (and nearly the rounds).
        assert_eq!(p.rounds(), 5);
    }

    #[test]
    fn prev_power_of_two_boundaries() {
        assert_eq!(prev_power_of_two(0), 1);
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(2), 2);
        assert_eq!(prev_power_of_two(3), 2);
        assert_eq!(prev_power_of_two(256), 256, "exact powers map to themselves");
        assert_eq!(prev_power_of_two(511), 256);
        assert_eq!(prev_power_of_two(512), 512);
    }

    #[test]
    fn sparse_plan_rounds() {
        let p = SparsePlan::new(1 << 20, 1 << 18, 2, 1e-5, 1e-4).unwrap();
        assert_eq!(p.q(), 4);
        assert_eq!(p.rounds(), 3);
    }

    #[test]
    fn sparse_plan_rejects_bad() {
        assert!(SparsePlan::new(100, 32, 1, 0.1, 0.1).is_err()); // 32 ∤ 100
        assert!(SparsePlan::new(128, 32, 3, 0.1, 0.1).is_err()); // 3 ∤ 4
        assert!(SparsePlan::new(128, 32, 1, -0.1, 0.1).is_err());
    }
}
