//! The 3D multi-round algorithm (paper Algorithm 1), generic over the
//! block payload so the dense and sparse variants share the map/route
//! logic that Theorem 3.1's proof pins down.
//!
//! With `q = √(n/m)` blocks per dimension and replication ρ, the q³
//! block products are partitioned into q groups
//! `G_ℓ = { A[i,h]·B[h,j] : h = (i+j+ℓ) mod q }`; round `r < R-1`
//! computes groups `rρ … (r+1)ρ-1`, maintaining ρ running accumulators
//! `C^ℓ'` per output block; the final round sums the ρ accumulators.
//!
//! Map of round `r` (from the proof of Theorem 3.1 — the pseudocode in
//! the paper omits the `rρ` term in the A/B cases):
//!
//! * `⟨(i,-1,k); A[i,k]⟩` → for ℓ' in 0..ρ: emit
//!   `⟨(i, k, (k-i-ℓ'-rρ) mod q); A⟩`
//! * `⟨(k,-1,j); B[k,j]⟩` → for ℓ' in 0..ρ: emit
//!   `⟨((k-j-ℓ'-rρ) mod q, k, j); B⟩`
//! * `⟨(i,ℓ',j); C^ℓ'⟩` → emit `⟨(i, (i+j+ℓ'+rρ) mod q, j); C^ℓ'⟩`,
//!   or `⟨(i,-1,j); C^ℓ'⟩` in the final round.
//!
//! Reduce of a product round at key `(i,h,j)`: `C^ℓ' ⊕= A[i,h]·B[h,j]`,
//! emitted as `⟨(i,ℓ',j); C^ℓ'⟩` with `ℓ' = (h-i-j-rρ) mod q < ρ`.
//! Reduce of the final round at `(i,-1,j)`: emit `⟨(i,-1,j); Σ_ℓ C^ℓ⟩`.

use std::marker::PhantomData;
use std::sync::Arc;

use crate::mapreduce::driver::MultiRoundAlgorithm;
use crate::mapreduce::types::{Mapper, Partitioner, Reducer, Value};

use super::keys::{umod, TripleKey};
use super::planner::Plan3d;

/// Which operand a block payload carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// A block of the left input matrix.
    A,
    /// A block of the right input matrix.
    B,
    /// A partial-sum accumulator block.
    C,
}

/// A block payload routed by the 3D algorithm.
pub trait Block3d: Value {
    /// Which operand this block is.
    fn tag(&self) -> Tag;
}

/// Payload-specific block algebra: the fused multiply-accumulate the
/// reducers run (dense → XLA/native GEMM; sparse → CSR SpGEMM) and the
/// final-round ρ-way sum.
pub trait BlockOps<P: Block3d>: Send + Sync {
    /// `c ⊕ a·b` (with `c` absent in round 0); result tagged [`Tag::C`].
    fn fma(&self, a: &P, b: &P, c: Option<&P>) -> P;
    /// `Σ parts` over ≥1 C blocks; result tagged [`Tag::C`].
    fn sum(&self, parts: Vec<P>) -> P;
}

/// Geometry shared by mapper and reducer.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// Blocks per dimension `q`.
    pub q: usize,
    /// Replication factor ρ.
    pub rho: usize,
}

impl Geometry {
    /// Rounds: `q/ρ + 1`.
    pub fn rounds(&self) -> usize {
        self.q / self.rho + 1
    }

    /// Is `r` the final (summation) round?
    pub fn is_final(&self, r: usize) -> bool {
        r + 1 == self.rounds()
    }
}

impl From<Plan3d> for Geometry {
    fn from(p: Plan3d) -> Self {
        Geometry {
            q: p.q(),
            rho: p.rho,
        }
    }
}

/// Map function of Algorithm 1.
pub struct Mapper3d<P> {
    geo: Geometry,
    _pd: PhantomData<fn() -> P>,
}

impl<P> Mapper3d<P> {
    /// New mapper for the given geometry.
    pub fn new(geo: Geometry) -> Self {
        Self {
            geo,
            _pd: PhantomData,
        }
    }
}

impl<P: Block3d> Mapper<TripleKey, P> for Mapper3d<P> {
    fn map(&self, round: usize, key: &TripleKey, value: &P, emit: &mut dyn FnMut(TripleKey, P)) {
        let Geometry { q, rho } = self.geo;
        let last = self.geo.is_final(round);
        match value.tag() {
            Tag::A => {
                if last {
                    return; // A is not consumed by the summation round
                }
                // key = (i, -1, k): block A[i,k]; k is the inner index.
                let (i, k) = (key.i as isize, key.j as isize);
                for l in 0..rho {
                    let j = umod(k - i - l as isize - (round * rho) as isize, q);
                    emit(
                        TripleKey::new(key.i as usize, key.j as usize, j),
                        value.clone(),
                    );
                }
            }
            Tag::B => {
                if last {
                    return;
                }
                // key = (k, -1, j): block B[k,j]; k is the inner index.
                let (k, j) = (key.i as isize, key.j as isize);
                for l in 0..rho {
                    let i = umod(k - j - l as isize - (round * rho) as isize, q);
                    emit(
                        TripleKey::new(i, key.i as usize, key.j as usize),
                        value.clone(),
                    );
                }
            }
            Tag::C => {
                // key = (i, ℓ', j): accumulator C^ℓ'.
                let (i, l, j) = (key.i as usize, key.h as usize, key.j as usize);
                debug_assert!(l < rho, "carry slot {l} out of range (rho={rho})");
                if last {
                    emit(TripleKey::io(i, j), value.clone());
                } else {
                    let h = (i + j + l + round * rho) % q;
                    emit(TripleKey::new(i, h, j), value.clone());
                }
            }
        }
    }
}

/// Reduce function of Algorithm 1.
pub struct Reducer3d<P: Block3d> {
    geo: Geometry,
    ops: Arc<dyn BlockOps<P>>,
}

impl<P: Block3d> Reducer3d<P> {
    /// New reducer with the payload algebra `ops`.
    pub fn new(geo: Geometry, ops: Arc<dyn BlockOps<P>>) -> Self {
        Self { geo, ops }
    }
}

impl<P: Block3d> Reducer<TripleKey, P> for Reducer3d<P> {
    fn reduce(
        &self,
        round: usize,
        key: &TripleKey,
        values: Vec<P>,
        emit: &mut dyn FnMut(TripleKey, P),
    ) {
        let Geometry { q, rho } = self.geo;
        if self.geo.is_final(round) {
            // Key (i,-1,j): sum the ρ accumulators.
            debug_assert!(key.is_io(), "final round key must be (i,-1,j): {key:?}");
            debug_assert!(
                values.iter().all(|v| v.tag() == Tag::C),
                "final round values must all be C"
            );
            let sum = self.ops.sum(values);
            emit(*key, sum);
            return;
        }
        // Product round at key (i,h,j): expect exactly one A, one B,
        // and (after round 0) one C.
        let mut a = None;
        let mut b = None;
        let mut c = None;
        for v in values {
            match v.tag() {
                Tag::A => {
                    assert!(a.is_none(), "duplicate A at {key:?}");
                    a = Some(v);
                }
                Tag::B => {
                    assert!(b.is_none(), "duplicate B at {key:?}");
                    b = Some(v);
                }
                Tag::C => {
                    assert!(c.is_none(), "duplicate C at {key:?}");
                    c = Some(v);
                }
            }
        }
        let a = a.unwrap_or_else(|| panic!("missing A at {key:?} round {round}"));
        let b = b.unwrap_or_else(|| panic!("missing B at {key:?} round {round}"));
        if round > 0 {
            assert!(c.is_some(), "missing C at {key:?} round {round}");
        }
        let result = self.ops.fma(&a, &b, c.as_ref());
        // ℓ' = (h - i - j - rρ) mod q, guaranteed < ρ for live keys.
        let l = umod(
            key.h as isize - key.i as isize - key.j as isize - (round * rho) as isize,
            q,
        );
        debug_assert!(l < rho, "reducer key {key:?} not live in round {round}");
        emit(
            TripleKey::carry(key.i as usize, l, key.j as usize),
            result,
        );
    }
}

/// The full 3D multi-round algorithm: geometry + payload algebra +
/// partitioner, pluggable into [`crate::mapreduce::Driver`].
pub struct Algo3d<P: Block3d> {
    geo: Geometry,
    mapper: Mapper3d<P>,
    reducer: Reducer3d<P>,
    partitioner: Box<dyn Partitioner<TripleKey>>,
}

impl<P: Block3d> Algo3d<P> {
    /// Assemble the algorithm.
    pub fn new(
        geo: Geometry,
        ops: Arc<dyn BlockOps<P>>,
        partitioner: Box<dyn Partitioner<TripleKey>>,
    ) -> Self {
        Self {
            geo,
            mapper: Mapper3d::new(geo),
            reducer: Reducer3d::new(geo, ops),
            partitioner,
        }
    }

    /// The geometry in use.
    pub fn geometry(&self) -> Geometry {
        self.geo
    }
}

impl<P: Block3d> MultiRoundAlgorithm for Algo3d<P> {
    type K = TripleKey;
    type V = P;

    fn num_rounds(&self) -> usize {
        self.geo.rounds()
    }

    fn mapper(&self, _round: usize) -> &dyn Mapper<TripleKey, P> {
        &self.mapper
    }

    fn reducer(&self, _round: usize) -> &dyn Reducer<TripleKey, P> {
        &self.reducer
    }

    fn partitioner(&self, _round: usize) -> &dyn Partitioner<TripleKey> {
        self.partitioner.as_ref()
    }

    fn reads_static_input(&self, round: usize) -> bool {
        // A and B are re-read from the DFS in every product round; the
        // final summation round reads only the carried accumulators.
        !self.geo.is_final(round)
    }

    fn groups_hint(&self, round: usize) -> Option<usize> {
        // Known analytically (asserted by `shuffle_and_reducer_bounds_hold`):
        // ρq² live (i,h,j) keys per product round, q² (i,-1,j) keys in
        // the summation round.
        let Geometry { q, rho } = self.geo;
        Some(if self.geo.is_final(round) {
            q * q
        } else {
            rho * q * q
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use std::collections::BTreeMap;

    /// A symbolic payload that records provenance instead of numbers:
    /// the product A[i,h]·B[h,j] is the symbol (i,h,j); an accumulator
    /// is the set of symbols summed so far. Routing is correct iff the
    /// final accumulator of output (i,j) is exactly
    /// { (i,h,j) : 0 ≤ h < q }.
    #[derive(Debug, Clone, PartialEq)]
    enum Sym {
        A { i: usize, k: usize },
        B { k: usize, j: usize },
        C { prods: Vec<(usize, usize, usize)> },
    }

    impl Value for Sym {
        fn words(&self) -> usize {
            match self {
                Sym::C { prods } => prods.len().max(1),
                _ => 1,
            }
        }
    }

    impl Block3d for Sym {
        fn tag(&self) -> Tag {
            match self {
                Sym::A { .. } => Tag::A,
                Sym::B { .. } => Tag::B,
                Sym::C { .. } => Tag::C,
            }
        }
    }

    struct SymOps;
    impl BlockOps<Sym> for SymOps {
        fn fma(&self, a: &Sym, b: &Sym, c: Option<&Sym>) -> Sym {
            let (i, k1) = match a {
                Sym::A { i, k } => (*i, *k),
                _ => panic!("fma: first operand not A"),
            };
            let (k2, j) = match b {
                Sym::B { k, j } => (*k, *j),
                _ => panic!("fma: second operand not B"),
            };
            assert_eq!(k1, k2, "inner indices must match: A[{i},{k1}]·B[{k2},{j}]");
            let mut prods = match c {
                Some(Sym::C { prods }) => prods.clone(),
                None => vec![],
                _ => panic!("fma: third operand not C"),
            };
            prods.push((i, k1, j));
            Sym::C { prods }
        }

        fn sum(&self, parts: Vec<Sym>) -> Sym {
            let mut prods = vec![];
            for p in parts {
                match p {
                    Sym::C { prods: ps } => prods.extend(ps),
                    _ => panic!("sum: non-C part"),
                }
            }
            Sym::C { prods }
        }
    }

    fn static_input(q: usize) -> Vec<crate::mapreduce::Pair<TripleKey, Sym>> {
        let mut out = vec![];
        for i in 0..q {
            for j in 0..q {
                out.push(crate::mapreduce::Pair::new(
                    TripleKey::io(i, j),
                    Sym::A { i, k: j },
                ));
                out.push(crate::mapreduce::Pair::new(
                    TripleKey::io(i, j),
                    Sym::B { k: i, j },
                ));
            }
        }
        out
    }

    fn run_symbolic(q: usize, rho: usize) -> BTreeMap<(usize, usize), Vec<(usize, usize, usize)>> {
        use crate::m3::partitioner::BalancedPartitioner3d;
        use crate::mapreduce::{Driver, EngineConfig};
        let geo = Geometry { q, rho };
        let alg = Algo3d::new(
            geo,
            Arc::new(SymOps),
            Box::new(BalancedPartitioner3d { q, rho }),
        );
        let mut driver = Driver::new(EngineConfig {
            map_tasks: 4,
            reduce_tasks: 4,
            workers: 4,
        });
        let res = driver.run(&alg, &static_input(q));
        let mut out = BTreeMap::new();
        for p in res.output {
            assert!(p.key.is_io(), "final keys must be (i,-1,j)");
            let prods = match p.value {
                Sym::C { mut prods } => {
                    prods.sort_unstable();
                    prods
                }
                _ => panic!("final value must be C"),
            };
            let prev = out.insert((p.key.i as usize, p.key.j as usize), prods);
            assert!(prev.is_none(), "duplicate output block");
        }
        out
    }

    fn expected(q: usize) -> BTreeMap<(usize, usize), Vec<(usize, usize, usize)>> {
        let mut out = BTreeMap::new();
        for i in 0..q {
            for j in 0..q {
                out.insert((i, j), (0..q).map(|h| (i, h, j)).collect());
            }
        }
        out
    }

    #[test]
    fn symbolic_routing_monolithic() {
        // ρ = q: two rounds.
        assert_eq!(run_symbolic(4, 4), expected(4));
    }

    #[test]
    fn symbolic_routing_extreme_multiround() {
        // ρ = 1: q+1 rounds.
        assert_eq!(run_symbolic(4, 1), expected(4));
    }

    #[test]
    fn symbolic_routing_intermediate() {
        assert_eq!(run_symbolic(8, 2), expected(8));
        assert_eq!(run_symbolic(8, 4), expected(8));
        assert_eq!(run_symbolic(6, 3), expected(6));
    }

    #[test]
    fn prop_symbolic_routing_all_geometries() {
        // Every (q, ρ | q) computes each product exactly once and routes
        // it to the right output block — the heart of Theorem 3.1.
        run_prop("3d routing correct", 12, |case| {
            let q = 1 + case.size(1, 9);
            let divisors: Vec<usize> = (1..=q).filter(|d| q % d == 0).collect();
            let rho = divisors[case.rng.next_usize(divisors.len())];
            let got = run_symbolic(q, rho);
            if got != expected(q) {
                return Err(format!("routing wrong at q={q} rho={rho}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mapper_fanout_is_rho() {
        let geo = Geometry { q: 4, rho: 2 };
        let m = Mapper3d::<Sym>::new(geo);
        let mut n = 0;
        m.map(0, &TripleKey::io(1, 2), &Sym::A { i: 1, k: 2 }, &mut |_, _| {
            n += 1
        });
        assert_eq!(n, 2, "A replicated ρ times");
        let mut n = 0;
        m.map(
            1,
            &TripleKey::carry(1, 0, 2),
            &Sym::C { prods: vec![] },
            &mut |_, _| n += 1,
        );
        assert_eq!(n, 1, "C emitted once");
    }

    #[test]
    fn mapper_ab_silent_in_final_round() {
        let geo = Geometry { q: 4, rho: 4 }; // rounds = 2, final = 1
        let m = Mapper3d::<Sym>::new(geo);
        let mut n = 0;
        m.map(1, &TripleKey::io(0, 0), &Sym::A { i: 0, k: 0 }, &mut |_, _| {
            n += 1
        });
        m.map(1, &TripleKey::io(0, 0), &Sym::B { k: 0, j: 0 }, &mut |_, _| {
            n += 1
        });
        assert_eq!(n, 0);
    }

    #[test]
    fn shuffle_and_reducer_bounds_hold() {
        // Theorem 3.1: per-round shuffle ≤ 3ρq² block-pairs; every
        // product-round reducer sees ≤ 3 blocks.
        use crate::m3::partitioner::BalancedPartitioner3d;
        use crate::mapreduce::{Driver, EngineConfig};
        let (q, rho) = (6, 2);
        let geo = Geometry { q, rho };
        let alg = Algo3d::new(
            geo,
            Arc::new(SymOps),
            Box::new(BalancedPartitioner3d { q, rho }),
        );
        let mut driver = Driver::new(EngineConfig {
            map_tasks: 2,
            reduce_tasks: 3,
            workers: 2,
        });
        let res = driver.run(&alg, &static_input(q));
        for (r, m) in res.metrics.rounds.iter().enumerate() {
            if r + 1 < geo.rounds() {
                assert!(
                    m.shuffle_pairs <= 3 * rho * q * q,
                    "round {r}: {} pairs > 3ρq²",
                    m.shuffle_pairs
                );
                assert_eq!(m.num_reducers, rho * q * q, "round {r} live reducers");
            } else {
                assert_eq!(m.shuffle_pairs, rho * q * q, "final round shuffles ρq² C blocks");
                assert_eq!(m.num_reducers, q * q);
            }
        }
    }

    #[test]
    #[should_panic(expected = "missing A")]
    fn reducer_rejects_incomplete_group() {
        let geo = Geometry { q: 4, rho: 1 };
        let red = Reducer3d::new(geo, Arc::new(SymOps) as Arc<dyn BlockOps<Sym>>);
        red.reduce(
            0,
            &TripleKey::new(0, 0, 0),
            vec![Sym::B { k: 0, j: 0 }],
            &mut |_, _| {},
        );
    }

    #[test]
    #[should_panic(expected = "duplicate A")]
    fn reducer_rejects_duplicate_operand() {
        let geo = Geometry { q: 4, rho: 1 };
        let red = Reducer3d::new(geo, Arc::new(SymOps) as Arc<dyn BlockOps<Sym>>);
        red.reduce(
            0,
            &TripleKey::new(0, 0, 0),
            vec![Sym::A { i: 0, k: 0 }, Sym::A { i: 0, k: 0 }],
            &mut |_, _| {},
        );
    }
}
