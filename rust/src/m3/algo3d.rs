//! The 3D multi-round algorithm (paper Algorithm 1), generic over the
//! block payload so the dense and sparse variants share the map/route
//! logic that Theorem 3.1's proof pins down.
//!
//! With `q = √(n/m)` blocks per dimension and replication ρ, the q³
//! block products are partitioned into q groups
//! `G_ℓ = { A[i,h]·B[h,j] : h = (i+j+ℓ) mod q }`; round `r < R-1`
//! computes groups `rρ … (r+1)ρ-1`, maintaining ρ running accumulators
//! `C^ℓ'` per output block; the final round sums the ρ accumulators.
//!
//! Map of round `r` (from the proof of Theorem 3.1 — the pseudocode in
//! the paper omits the `rρ` term in the A/B cases):
//!
//! * `⟨(i,-1,k); A[i,k]⟩` → for ℓ' in 0..ρ: emit
//!   `⟨(i, k, (k-i-ℓ'-rρ) mod q); A⟩`
//! * `⟨(k,-1,j); B[k,j]⟩` → for ℓ' in 0..ρ: emit
//!   `⟨((k-j-ℓ'-rρ) mod q, k, j); B⟩`
//! * `⟨(i,ℓ',j); C^ℓ'⟩` → emit `⟨(i, (i+j+ℓ'+rρ) mod q, j); C^ℓ'⟩`,
//!   or `⟨(i,-1,j); C^ℓ'⟩` in the final round.
//!
//! Reduce of a product round at key `(i,h,j)`: `C^ℓ' ⊕= A[i,h]·B[h,j]`,
//! emitted as `⟨(i,ℓ',j); C^ℓ'⟩` with `ℓ' = (h-i-j-rρ) mod q < ρ`.
//! Reduce of the final round at `(i,-1,j)`: emit `⟨(i,-1,j); Σ_ℓ C^ℓ⟩`.

use std::marker::PhantomData;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::mapreduce::driver::MultiRoundAlgorithm;
use crate::mapreduce::types::{Mapper, Partitioner, Reducer, Value};

use super::keys::{umod, TripleKey};
use super::planner::Plan3d;

/// Which operand a block payload carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// A block of the left input matrix.
    A,
    /// A block of the right input matrix.
    B,
    /// A partial-sum accumulator block.
    C,
}

/// A block payload routed by the 3D algorithm.
pub trait Block3d: Value {
    /// Which operand this block is.
    fn tag(&self) -> Tag;

    /// The wire codec for `(TripleKey, Self)` pairs, when the payload
    /// is serializable. `None` (the default — symbolic test payloads)
    /// keeps the zero-copy shuffle; the real dense/sparse blocks
    /// override it, which is what lets [`Algo3d`] run on a serialized
    /// transport.
    fn wire_codec() -> Option<crate::mapreduce::wire::CodecHandle<TripleKey, Self>>
    where
        Self: Sized,
    {
        None
    }
}

/// Payload-specific block algebra: the fused multiply-accumulate the
/// reducers run (dense → XLA/native GEMM; sparse → CSR SpGEMM) and the
/// final-round ρ-way sum.
pub trait BlockOps<P: Block3d>: Send + Sync {
    /// `c ⊕ a·b` (with `c` absent in round 0); result tagged [`Tag::C`].
    fn fma(&self, a: &P, b: &P, c: Option<&P>) -> P;
    /// `Σ parts` over ≥1 C blocks; result tagged [`Tag::C`].
    fn sum(&self, parts: Vec<P>) -> P;
}

/// Geometry shared by mapper and reducer.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// Blocks per dimension `q`.
    pub q: usize,
    /// Replication factor ρ.
    pub rho: usize,
}

impl Geometry {
    /// Rounds: `q/ρ + 1`.
    pub fn rounds(&self) -> usize {
        self.q / self.rho + 1
    }

    /// Is `r` the final (summation) round?
    pub fn is_final(&self, r: usize) -> bool {
        r + 1 == self.rounds()
    }
}

impl From<Plan3d> for Geometry {
    fn from(p: Plan3d) -> Self {
        Geometry {
            q: p.q(),
            rho: p.rho,
        }
    }
}

/// A per-round ρ *schedule*: product round `r` computes `widths[r]`
/// consecutive groups, with `Σ widths = q`. Uniform widths are the
/// paper's fixed-ρ plan; a non-uniform tail is what the auto-planner's
/// mid-job re-plan installs on the pending rounds.
///
/// Widths must be **non-decreasing**: round `r` carries `widths[r-1]`
/// accumulator slots into round `r`, where slots `< widths[r-1]` keep
/// accumulating and slots `[widths[r-1], widths[r])` start fresh. A
/// shrinking width would strand accumulators with no group to join —
/// hence re-plans may only widen the tail (fewer remaining rounds),
/// never narrow it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RhoSchedule {
    q: usize,
    widths: Vec<usize>,
    /// `offsets[r]` = first group of product round `r` (prefix sums of
    /// `widths`, precomputed: [`Self::offset`] sits on the per-key
    /// mapper/reducer hot path).
    offsets: Vec<usize>,
}

impl RhoSchedule {
    /// Validate and construct a schedule over `q` groups.
    pub fn new(q: usize, widths: Vec<usize>) -> Result<Self> {
        if q == 0 || widths.is_empty() {
            bail!("schedule needs q ≥ 1 and at least one product round");
        }
        if widths.iter().any(|&w| w == 0) {
            bail!("round widths must be positive: {widths:?}");
        }
        if widths.windows(2).any(|w| w[1] < w[0]) {
            bail!("round widths must be non-decreasing: {widths:?}");
        }
        let total: usize = widths.iter().sum();
        if total != q {
            bail!("round widths sum to {total}, expected q = {q}");
        }
        let mut offsets = Vec::with_capacity(widths.len());
        let mut acc = 0usize;
        for &w in &widths {
            offsets.push(acc);
            acc += w;
        }
        Ok(Self { q, widths, offsets })
    }

    /// The uniform schedule of a fixed-ρ plan (`q/ρ` rounds of `ρ`).
    ///
    /// # Panics
    /// Panics unless `1 ≤ ρ ≤ q` and `ρ | q` (what [`Plan3d`] validates).
    pub fn uniform(q: usize, rho: usize) -> Self {
        assert!(
            (1..=q).contains(&rho) && q % rho == 0,
            "invalid uniform rho={rho} q={q}"
        );
        Self::new(q, vec![rho; q / rho]).expect("uniform schedules are valid by construction")
    }

    /// Blocks per dimension `q`.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Per-product-round group widths.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Number of product rounds.
    pub fn product_rounds(&self) -> usize {
        self.widths.len()
    }

    /// Total rounds (product rounds + the final summation round).
    pub fn rounds(&self) -> usize {
        self.widths.len() + 1
    }

    /// Is `r` the final (summation) round?
    pub fn is_final(&self, r: usize) -> bool {
        r + 1 == self.rounds()
    }

    /// Width of product round `r`.
    pub fn width(&self, r: usize) -> usize {
        self.widths[r]
    }

    /// First group index of product round `r` (precomputed prefix sum).
    pub fn offset(&self, r: usize) -> usize {
        self.offsets[r]
    }

    /// Accumulator slots carried *into* round `r` (0 for round 0; the
    /// final round receives the last product round's width).
    pub fn carried_width(&self, r: usize) -> usize {
        if r == 0 {
            0
        } else {
            self.widths[r - 1]
        }
    }

    /// Replace the widths from product round `from_round` on with
    /// `tail`, keeping the committed prefix; the combined schedule is
    /// re-validated (sum `q`, non-decreasing across the splice).
    pub fn with_tail(&self, from_round: usize, tail: Vec<usize>) -> Result<Self> {
        if from_round > self.widths.len() {
            bail!(
                "tail starts at product round {from_round}, schedule has {}",
                self.widths.len()
            );
        }
        let mut widths = self.widths[..from_round].to_vec();
        widths.extend(tail);
        Self::new(self.q, widths)
    }
}

impl From<Geometry> for RhoSchedule {
    fn from(g: Geometry) -> Self {
        RhoSchedule::uniform(g.q, g.rho)
    }
}

/// Map function of Algorithm 1.
pub struct Mapper3d<P> {
    sched: RhoSchedule,
    _pd: PhantomData<fn() -> P>,
}

impl<P> Mapper3d<P> {
    /// New mapper for the given (uniform-ρ) geometry.
    pub fn new(geo: Geometry) -> Self {
        Self::with_schedule(geo.into())
    }

    /// New mapper for an explicit ρ schedule.
    pub fn with_schedule(sched: RhoSchedule) -> Self {
        Self {
            sched,
            _pd: PhantomData,
        }
    }
}

impl<P: Block3d> Mapper<TripleKey, P> for Mapper3d<P> {
    fn map(&self, round: usize, key: &TripleKey, value: &P, emit: &mut dyn FnMut(TripleKey, P)) {
        let q = self.sched.q();
        let last = self.sched.is_final(round);
        match value.tag() {
            Tag::A => {
                if last {
                    return; // A is not consumed by the summation round
                }
                // key = (i, -1, k): block A[i,k]; k is the inner index.
                // Round `round` computes groups offset..offset+width.
                let offset = self.sched.offset(round) as isize;
                let (i, k) = (key.i as isize, key.j as isize);
                for l in 0..self.sched.width(round) {
                    let j = umod(k - i - l as isize - offset, q);
                    emit(
                        TripleKey::new(key.i as usize, key.j as usize, j),
                        value.clone(),
                    );
                }
            }
            Tag::B => {
                if last {
                    return;
                }
                // key = (k, -1, j): block B[k,j]; k is the inner index.
                let offset = self.sched.offset(round) as isize;
                let (k, j) = (key.i as isize, key.j as isize);
                for l in 0..self.sched.width(round) {
                    let i = umod(k - j - l as isize - offset, q);
                    emit(
                        TripleKey::new(i, key.i as usize, key.j as usize),
                        value.clone(),
                    );
                }
            }
            Tag::C => {
                // key = (i, ℓ', j): accumulator C^ℓ' from the previous
                // round, which had `carried_width(round)` slots.
                let (i, l, j) = (key.i as usize, key.h as usize, key.j as usize);
                debug_assert!(
                    l < self.sched.carried_width(round),
                    "carry slot {l} out of range (round {round})"
                );
                if last {
                    emit(TripleKey::io(i, j), value.clone());
                } else {
                    // Slot ℓ' continues as group offset+ℓ' this round.
                    let h = (i + j + l + self.sched.offset(round)) % q;
                    emit(TripleKey::new(i, h, j), value.clone());
                }
            }
        }
    }
}

/// Reduce function of Algorithm 1.
pub struct Reducer3d<P: Block3d> {
    sched: RhoSchedule,
    ops: Arc<dyn BlockOps<P>>,
}

impl<P: Block3d> Reducer3d<P> {
    /// New reducer with the payload algebra `ops` (uniform-ρ geometry).
    pub fn new(geo: Geometry, ops: Arc<dyn BlockOps<P>>) -> Self {
        Self::with_schedule(geo.into(), ops)
    }

    /// New reducer for an explicit ρ schedule.
    pub fn with_schedule(sched: RhoSchedule, ops: Arc<dyn BlockOps<P>>) -> Self {
        Self { sched, ops }
    }
}

impl<P: Block3d> Reducer<TripleKey, P> for Reducer3d<P> {
    fn reduce(
        &self,
        round: usize,
        key: &TripleKey,
        values: Vec<P>,
        emit: &mut dyn FnMut(TripleKey, P),
    ) {
        let q = self.sched.q();
        if self.sched.is_final(round) {
            // Key (i,-1,j): sum the ρ accumulators.
            debug_assert!(key.is_io(), "final round key must be (i,-1,j): {key:?}");
            debug_assert!(
                values.iter().all(|v| v.tag() == Tag::C),
                "final round values must all be C"
            );
            let sum = self.ops.sum(values);
            emit(*key, sum);
            return;
        }
        // Product round at key (i,h,j): expect exactly one A, one B,
        // and (after round 0) one C.
        let mut a = None;
        let mut b = None;
        let mut c = None;
        for v in values {
            match v.tag() {
                Tag::A => {
                    assert!(a.is_none(), "duplicate A at {key:?}");
                    a = Some(v);
                }
                Tag::B => {
                    assert!(b.is_none(), "duplicate B at {key:?}");
                    b = Some(v);
                }
                Tag::C => {
                    assert!(c.is_none(), "duplicate C at {key:?}");
                    c = Some(v);
                }
            }
        }
        let a = a.unwrap_or_else(|| panic!("missing A at {key:?} round {round}"));
        let b = b.unwrap_or_else(|| panic!("missing B at {key:?} round {round}"));
        // ℓ' = (h - i - j - offset) mod q, guaranteed < width for live
        // keys. Slots below the carried width continue an accumulator
        // from the previous round; slots at or above it (the widened
        // part of a re-planned tail, or all of round 0) start fresh.
        let l = umod(
            key.h as isize
                - key.i as isize
                - key.j as isize
                - self.sched.offset(round) as isize,
            q,
        );
        debug_assert!(
            l < self.sched.width(round),
            "reducer key {key:?} not live in round {round}"
        );
        if l < self.sched.carried_width(round) {
            assert!(c.is_some(), "missing C at {key:?} round {round}");
        } else {
            assert!(c.is_none(), "unexpected C on a fresh slot at {key:?} round {round}");
        }
        let result = self.ops.fma(&a, &b, c.as_ref());
        emit(
            TripleKey::carry(key.i as usize, l, key.j as usize),
            result,
        );
    }
}

/// The full 3D multi-round algorithm: ρ schedule + payload algebra +
/// partitioner, pluggable into [`crate::mapreduce::Driver`].
pub struct Algo3d<P: Block3d> {
    sched: RhoSchedule,
    ops: Arc<dyn BlockOps<P>>,
    mapper: Mapper3d<P>,
    reducer: Reducer3d<P>,
    partitioner: Box<dyn Partitioner<TripleKey>>,
}

impl<P: Block3d> Algo3d<P> {
    /// Assemble the algorithm for a uniform-ρ geometry.
    pub fn new(
        geo: Geometry,
        ops: Arc<dyn BlockOps<P>>,
        partitioner: Box<dyn Partitioner<TripleKey>>,
    ) -> Self {
        Self::with_schedule(geo.into(), ops, partitioner)
    }

    /// Assemble the algorithm for an explicit ρ schedule.
    pub fn with_schedule(
        sched: RhoSchedule,
        ops: Arc<dyn BlockOps<P>>,
        partitioner: Box<dyn Partitioner<TripleKey>>,
    ) -> Self {
        Self {
            mapper: Mapper3d::with_schedule(sched.clone()),
            reducer: Reducer3d::with_schedule(sched.clone(), ops.clone()),
            sched,
            ops,
            partitioner,
        }
    }

    /// The ρ schedule in use.
    pub fn schedule(&self) -> &RhoSchedule {
        &self.sched
    }

    /// Re-plan the rounds from product round `from_round` on with a new
    /// width sequence (the committed prefix is untouched, so a resumable
    /// run may call this at any round boundary ≤ its next pending
    /// round). The new tail must keep the schedule non-decreasing and
    /// group-complete; the round count shrinks when the tail widens.
    /// The partitioner is kept as constructed — partitioning is
    /// correctness-neutral, so a widened round may spread its extra
    /// keys slightly less evenly than a dedicated partitioner would.
    pub fn set_tail_widths(&mut self, from_round: usize, tail: Vec<usize>) -> Result<()> {
        let sched = self.sched.with_tail(from_round, tail)?;
        self.mapper = Mapper3d::with_schedule(sched.clone());
        self.reducer = Reducer3d::with_schedule(sched.clone(), self.ops.clone());
        self.sched = sched;
        Ok(())
    }
}

impl<P: Block3d> MultiRoundAlgorithm for Algo3d<P> {
    type K = TripleKey;
    type V = P;

    fn num_rounds(&self) -> usize {
        self.sched.rounds()
    }

    fn mapper(&self, _round: usize) -> &dyn Mapper<TripleKey, P> {
        &self.mapper
    }

    fn reducer(&self, _round: usize) -> &dyn Reducer<TripleKey, P> {
        &self.reducer
    }

    fn partitioner(&self, _round: usize) -> &dyn Partitioner<TripleKey> {
        self.partitioner.as_ref()
    }

    fn reads_static_input(&self, round: usize) -> bool {
        // A and B are re-read from the DFS in every product round; the
        // final summation round reads only the carried accumulators.
        !self.sched.is_final(round)
    }

    fn groups_hint(&self, round: usize) -> Option<usize> {
        // Known analytically (asserted by `shuffle_and_reducer_bounds_hold`):
        // width·q² live (i,h,j) keys per product round, q² (i,-1,j)
        // keys in the summation round.
        let q = self.sched.q();
        Some(if self.sched.is_final(round) {
            q * q
        } else {
            self.sched.width(round) * q * q
        })
    }

    fn codec(&self) -> Option<crate::mapreduce::wire::CodecHandle<TripleKey, P>> {
        P::wire_codec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use std::collections::BTreeMap;

    /// A symbolic payload that records provenance instead of numbers:
    /// the product A[i,h]·B[h,j] is the symbol (i,h,j); an accumulator
    /// is the set of symbols summed so far. Routing is correct iff the
    /// final accumulator of output (i,j) is exactly
    /// { (i,h,j) : 0 ≤ h < q }.
    #[derive(Debug, Clone, PartialEq)]
    enum Sym {
        A { i: usize, k: usize },
        B { k: usize, j: usize },
        C { prods: Vec<(usize, usize, usize)> },
    }

    impl Value for Sym {
        fn words(&self) -> usize {
            match self {
                Sym::C { prods } => prods.len().max(1),
                _ => 1,
            }
        }
    }

    impl Block3d for Sym {
        fn tag(&self) -> Tag {
            match self {
                Sym::A { .. } => Tag::A,
                Sym::B { .. } => Tag::B,
                Sym::C { .. } => Tag::C,
            }
        }
    }

    struct SymOps;
    impl BlockOps<Sym> for SymOps {
        fn fma(&self, a: &Sym, b: &Sym, c: Option<&Sym>) -> Sym {
            let (i, k1) = match a {
                Sym::A { i, k } => (*i, *k),
                _ => panic!("fma: first operand not A"),
            };
            let (k2, j) = match b {
                Sym::B { k, j } => (*k, *j),
                _ => panic!("fma: second operand not B"),
            };
            assert_eq!(k1, k2, "inner indices must match: A[{i},{k1}]·B[{k2},{j}]");
            let mut prods = match c {
                Some(Sym::C { prods }) => prods.clone(),
                None => vec![],
                _ => panic!("fma: third operand not C"),
            };
            prods.push((i, k1, j));
            Sym::C { prods }
        }

        fn sum(&self, parts: Vec<Sym>) -> Sym {
            let mut prods = vec![];
            for p in parts {
                match p {
                    Sym::C { prods: ps } => prods.extend(ps),
                    _ => panic!("sum: non-C part"),
                }
            }
            Sym::C { prods }
        }
    }

    fn static_input(q: usize) -> Vec<crate::mapreduce::Pair<TripleKey, Sym>> {
        let mut out = vec![];
        for i in 0..q {
            for j in 0..q {
                out.push(crate::mapreduce::Pair::new(
                    TripleKey::io(i, j),
                    Sym::A { i, k: j },
                ));
                out.push(crate::mapreduce::Pair::new(
                    TripleKey::io(i, j),
                    Sym::B { k: i, j },
                ));
            }
        }
        out
    }

    type SymProducts = BTreeMap<(usize, usize), Vec<(usize, usize, usize)>>;

    fn collect_symbolic(res: crate::mapreduce::driver::RunResult<TripleKey, Sym>) -> SymProducts {
        let mut out = BTreeMap::new();
        for p in res.output {
            assert!(p.key.is_io(), "final keys must be (i,-1,j)");
            let prods = match p.value {
                Sym::C { mut prods } => {
                    prods.sort_unstable();
                    prods
                }
                _ => panic!("final value must be C"),
            };
            let prev = out.insert((p.key.i as usize, p.key.j as usize), prods);
            assert!(prev.is_none(), "duplicate output block");
        }
        out
    }

    fn run_symbolic(q: usize, rho: usize) -> SymProducts {
        run_symbolic_schedule(RhoSchedule::uniform(q, rho))
    }

    fn run_symbolic_schedule(sched: RhoSchedule) -> SymProducts {
        use crate::m3::partitioner::BalancedPartitioner3d;
        use crate::mapreduce::{Driver, EngineConfig};
        let q = sched.q();
        let rho = *sched.widths().last().unwrap();
        let alg = Algo3d::with_schedule(
            sched,
            Arc::new(SymOps),
            Box::new(BalancedPartitioner3d { q, rho }),
        );
        let mut driver = Driver::new(EngineConfig {
            map_tasks: 4,
            reduce_tasks: 4,
            workers: 4,
        });
        collect_symbolic(driver.run(&alg, &static_input(q)))
    }

    fn expected(q: usize) -> BTreeMap<(usize, usize), Vec<(usize, usize, usize)>> {
        let mut out = BTreeMap::new();
        for i in 0..q {
            for j in 0..q {
                out.insert((i, j), (0..q).map(|h| (i, h, j)).collect());
            }
        }
        out
    }

    #[test]
    fn symbolic_routing_monolithic() {
        // ρ = q: two rounds.
        assert_eq!(run_symbolic(4, 4), expected(4));
    }

    #[test]
    fn symbolic_routing_extreme_multiround() {
        // ρ = 1: q+1 rounds.
        assert_eq!(run_symbolic(4, 1), expected(4));
    }

    #[test]
    fn symbolic_routing_intermediate() {
        assert_eq!(run_symbolic(8, 2), expected(8));
        assert_eq!(run_symbolic(8, 4), expected(8));
        assert_eq!(run_symbolic(6, 3), expected(6));
    }

    #[test]
    fn prop_symbolic_routing_all_geometries() {
        // Every (q, ρ | q) computes each product exactly once and routes
        // it to the right output block — the heart of Theorem 3.1.
        run_prop("3d routing correct", 12, |case| {
            let q = 1 + case.size(1, 9);
            let divisors: Vec<usize> = (1..=q).filter(|d| q % d == 0).collect();
            let rho = divisors[case.rng.next_usize(divisors.len())];
            let got = run_symbolic(q, rho);
            if got != expected(q) {
                return Err(format!("routing wrong at q={q} rho={rho}"));
            }
            Ok(())
        });
    }

    #[test]
    fn symbolic_routing_non_uniform_schedules() {
        // Non-decreasing width schedules cover every group exactly once:
        // widened tails (the mid-job re-plan shape) route identically to
        // the uniform plans they replace.
        for widths in [vec![1, 1, 2, 4], vec![2, 6], vec![1, 3, 4], vec![8]] {
            let sched = RhoSchedule::new(8, widths.clone()).unwrap();
            assert_eq!(
                run_symbolic_schedule(sched),
                expected(8),
                "widths {widths:?}"
            );
        }
        for widths in [vec![1, 2, 3], vec![3, 3], vec![1, 1, 2, 2]] {
            let sched = RhoSchedule::new(6, widths.clone()).unwrap();
            assert_eq!(
                run_symbolic_schedule(sched),
                expected(6),
                "widths {widths:?}"
            );
        }
    }

    #[test]
    fn prop_symbolic_routing_random_schedules() {
        // Random valid (non-decreasing, q-complete) schedules all route
        // correctly — the re-planner may install any of them.
        run_prop("3d routing correct under schedules", 12, |case| {
            let q = 2 + case.size(1, 10);
            let mut widths = vec![];
            let mut left = q;
            let mut floor = 1usize;
            while left > 0 {
                let w = (floor + case.rng.next_usize(left.saturating_sub(floor) + 1)).min(left);
                // Keep the remainder coverable: the last width may need
                // to swallow whatever is left, which stays ≥ floor.
                if left - w > 0 && left - w < w {
                    widths.push(left);
                    break;
                }
                widths.push(w);
                floor = w;
                left -= w;
            }
            let sched = match RhoSchedule::new(q, widths.clone()) {
                Ok(s) => s,
                Err(e) => return Err(format!("generator made invalid {widths:?}: {e}")),
            };
            if run_symbolic_schedule(sched) != expected(q) {
                return Err(format!("routing wrong at q={q} widths={widths:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mid_run_tail_replan_preserves_the_product() {
        // Commit two ρ=1 rounds of a q=8 run, then widen the pending
        // tail to [2, 4]: the committed prefix's accumulators must flow
        // into the re-planned rounds and the output stay exact.
        use crate::m3::partitioner::BalancedPartitioner3d;
        use crate::mapreduce::{EngineConfig, StepRun};
        let q = 8;
        let alg = Algo3d::new(
            Geometry { q, rho: 1 },
            Arc::new(SymOps),
            Box::new(BalancedPartitioner3d { q, rho: 4 }),
        );
        let cfg = EngineConfig {
            map_tasks: 4,
            reduce_tasks: 4,
            workers: 4,
        };
        let mut run = StepRun::new(cfg, alg, static_input(q));
        assert_eq!(run.num_rounds(), 9);
        run.step_commit();
        run.step_commit();
        run.alg_mut().set_tail_widths(2, vec![2, 4]).unwrap();
        assert_eq!(run.num_rounds(), 5, "widths [1,1,2,4] + final");
        assert_eq!(run.next_round(), 2);
        while !run.is_done() {
            run.step_commit();
        }
        assert_eq!(collect_symbolic(run.into_result()), expected(q));
    }

    #[test]
    fn schedule_validation_rejects_bad_widths() {
        assert!(RhoSchedule::new(8, vec![4, 2, 2]).is_err(), "decreasing");
        assert!(RhoSchedule::new(8, vec![2, 2]).is_err(), "incomplete");
        assert!(RhoSchedule::new(8, vec![2, 2, 2, 2, 2]).is_err(), "overfull");
        assert!(RhoSchedule::new(8, vec![]).is_err(), "empty");
        assert!(RhoSchedule::new(8, vec![0, 8]).is_err(), "zero width");
        assert!(RhoSchedule::new(0, vec![1]).is_err(), "q = 0");
        let s = RhoSchedule::new(8, vec![1, 3, 4]).unwrap();
        assert_eq!(s.rounds(), 4);
        assert_eq!(s.offset(2), 4);
        assert_eq!(s.carried_width(0), 0);
        assert_eq!(s.carried_width(2), 3);
        assert!(s.with_tail(1, vec![7]).is_ok());
        assert!(s.with_tail(1, vec![3, 4]).is_ok());
        assert!(s.with_tail(2, vec![2, 2]).is_err(), "tail must keep the sum");
        assert!(s.with_tail(4, vec![]).is_err(), "past the last product round");
    }

    #[test]
    fn mapper_fanout_is_rho() {
        let geo = Geometry { q: 4, rho: 2 };
        let m = Mapper3d::<Sym>::new(geo);
        let mut n = 0;
        m.map(0, &TripleKey::io(1, 2), &Sym::A { i: 1, k: 2 }, &mut |_, _| {
            n += 1
        });
        assert_eq!(n, 2, "A replicated ρ times");
        let mut n = 0;
        m.map(
            1,
            &TripleKey::carry(1, 0, 2),
            &Sym::C { prods: vec![] },
            &mut |_, _| n += 1,
        );
        assert_eq!(n, 1, "C emitted once");
    }

    #[test]
    fn mapper_ab_silent_in_final_round() {
        let geo = Geometry { q: 4, rho: 4 }; // rounds = 2, final = 1
        let m = Mapper3d::<Sym>::new(geo);
        let mut n = 0;
        m.map(1, &TripleKey::io(0, 0), &Sym::A { i: 0, k: 0 }, &mut |_, _| {
            n += 1
        });
        m.map(1, &TripleKey::io(0, 0), &Sym::B { k: 0, j: 0 }, &mut |_, _| {
            n += 1
        });
        assert_eq!(n, 0);
    }

    #[test]
    fn shuffle_and_reducer_bounds_hold() {
        // Theorem 3.1: per-round shuffle ≤ 3ρq² block-pairs; every
        // product-round reducer sees ≤ 3 blocks.
        use crate::m3::partitioner::BalancedPartitioner3d;
        use crate::mapreduce::{Driver, EngineConfig};
        let (q, rho) = (6, 2);
        let geo = Geometry { q, rho };
        let alg = Algo3d::new(
            geo,
            Arc::new(SymOps),
            Box::new(BalancedPartitioner3d { q, rho }),
        );
        let mut driver = Driver::new(EngineConfig {
            map_tasks: 2,
            reduce_tasks: 3,
            workers: 2,
        });
        let res = driver.run(&alg, &static_input(q));
        for (r, m) in res.metrics.rounds.iter().enumerate() {
            if r + 1 < geo.rounds() {
                assert!(
                    m.shuffle_pairs <= 3 * rho * q * q,
                    "round {r}: {} pairs > 3ρq²",
                    m.shuffle_pairs
                );
                assert_eq!(m.num_reducers, rho * q * q, "round {r} live reducers");
            } else {
                assert_eq!(m.shuffle_pairs, rho * q * q, "final round shuffles ρq² C blocks");
                assert_eq!(m.num_reducers, q * q);
            }
        }
    }

    #[test]
    #[should_panic(expected = "missing A")]
    fn reducer_rejects_incomplete_group() {
        let geo = Geometry { q: 4, rho: 1 };
        let red = Reducer3d::new(geo, Arc::new(SymOps) as Arc<dyn BlockOps<Sym>>);
        red.reduce(
            0,
            &TripleKey::new(0, 0, 0),
            vec![Sym::B { k: 0, j: 0 }],
            &mut |_, _| {},
        );
    }

    #[test]
    #[should_panic(expected = "duplicate A")]
    fn reducer_rejects_duplicate_operand() {
        let geo = Geometry { q: 4, rho: 1 };
        let red = Reducer3d::new(geo, Arc::new(SymOps) as Arc<dyn BlockOps<Sym>>);
        red.reduce(
            0,
            &TripleKey::new(0, 0, 0),
            vec![Sym::A { i: 0, k: 0 }, Sym::A { i: 0, k: 0 }],
            &mut |_, _| {},
        );
    }
}
