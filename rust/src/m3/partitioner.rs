//! Partitioners for the M3 keys.
//!
//! The paper (§4.3, Figure 1) shows that the "common" hash partitioner
//! `t = (31²·i + 31·j + k) mod T` leaves reduce tasks badly unbalanced,
//! and proposes Algorithm 3: enumerate the round's live keys contiguously
//! in `[0, ρ·n/m)` by a row-major ordering of `(i, j, h mod ρ)`, then
//! deal them out in equal consecutive chunks of `B = ⌊ρn/(mT)⌋`, with
//! the ≤ T−1 leftover keys scattered.

use crate::mapreduce::types::Partitioner;

use super::keys::{PairKey, TripleKey};

/// The naive Java-style hash partitioner of Figure 1 (left).
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveTriplePartitioner;

impl Partitioner<TripleKey> for NaiveTriplePartitioner {
    fn partition(&self, key: &TripleKey, num_tasks: usize) -> usize {
        let h = 31i64 * 31 * key.i as i64 + 31 * key.h as i64 + key.j as i64;
        (h.rem_euclid(num_tasks as i64)) as usize
    }
}

/// Deterministic scatter for the ≤ T−1 leftover keys (the paper uses a
/// random task; a splitmix hash keeps runs reproducible while remaining
/// uniform).
fn scatter(z: usize, num_tasks: usize) -> usize {
    let mut x = z as u64;
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    ((x ^ (x >> 31)) % num_tasks as u64) as usize
}

/// Equal-chunk dealing of a contiguous key id `z ∈ [0, domain)` over
/// `T` tasks (paper Algorithm 3's core).
fn balanced(z: usize, domain: usize, num_tasks: usize) -> usize {
    let b = domain / num_tasks;
    if b > 0 && z < b * num_tasks {
        z / b
    } else {
        scatter(z, num_tasks)
    }
}

/// Paper Algorithm 3: balanced partitioner for the 3D algorithms.
///
/// Product-round keys `(i,h,j)` map to `z = (i·q + j)·ρ + (h mod ρ)`
/// (row-major on `(i, j, h')`; the paper prints `iρn/m` for the leading
/// stride, a typo for `i·ρ·√(n/m)` — the row-major stride over `j·ρ +
/// h'`). Final-round keys `(i,-1,j)` map to `z = i·q + j` over `[0,q²)`.
#[derive(Debug, Clone, Copy)]
pub struct BalancedPartitioner3d {
    /// Blocks per dimension `q`.
    pub q: usize,
    /// Replication factor ρ.
    pub rho: usize,
}

impl Partitioner<TripleKey> for BalancedPartitioner3d {
    fn partition(&self, key: &TripleKey, num_tasks: usize) -> usize {
        let (i, j) = (key.i as usize, key.j as usize);
        if key.is_io() {
            // Final round: q² keys (i,-1,j).
            let z = i * self.q + j;
            balanced(z, self.q * self.q, num_tasks)
        } else {
            let h_prime = (key.h as usize) % self.rho;
            let z = (i * self.q + j) * self.rho + h_prime;
            balanced(z, self.rho * self.q * self.q, num_tasks)
        }
    }
}

/// Balanced partitioner for the 2D algorithm ("a slightly different
/// approach", §4.3): round-`r` keys `(i, j)` with
/// `j = (i + ℓ + rρ) mod s` map to `z = i·ρ + ((j − i) mod ρ)` over
/// `[0, ρ·s)` (residues of consecutive offsets mod ρ are distinct
/// because ρ | s).
#[derive(Debug, Clone, Copy)]
pub struct BalancedPartitioner2d {
    /// Strips per matrix `s = n/m`.
    pub strips: usize,
    /// Replication factor ρ.
    pub rho: usize,
}

impl Partitioner<PairKey> for BalancedPartitioner2d {
    fn partition(&self, key: &PairKey, num_tasks: usize) -> usize {
        let i = key.i as usize;
        let j = key.j as usize;
        let off = (j + self.strips - (i % self.strips)) % self.strips;
        let z = i * self.rho + off % self.rho;
        balanced(z, self.rho * self.strips, num_tasks)
    }
}

/// Partitioner for the Strassen schedule's `(path, role, pos)` keys
/// ([`crate::m3::strassen::AlgoStrassen`]).
///
/// The live key domain changes shape every round (forward splits,
/// base-case products, combine merges), so unlike Algorithm 3 there is
/// no single contiguous enumeration to deal out in chunks; instead
/// every key gets the splitmix scatter over an injective id
/// `z = (path·3 + role)·4^L + pos` — uniform in expectation for every
/// round's domain, and reproducible.
#[derive(Debug, Clone, Copy)]
pub struct StrassenPartitioner {
    /// Recursion depth `L ≥ 1`.
    pub levels: usize,
}

impl Partitioner<TripleKey> for StrassenPartitioner {
    fn partition(&self, key: &TripleKey, num_tasks: usize) -> usize {
        // `pos < 4^L` in every round (forward positions shrink, combine
        // positions grow back to the 2^L × 2^L output grid), so z is
        // injective over the union of all rounds' key domains.
        // h = -1 (io keys) never reaches the shuffle, but clamp anyway.
        let (path, role, pos) = (key.i as usize, key.h.max(0) as usize, key.j as usize);
        let z = (path * 3 + role) * (1usize << (2 * self.levels)) + pos;
        scatter(z, num_tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::stats;

    /// The live reducer keys of round `r` of the 3D algorithm.
    fn round_keys(q: usize, rho: usize, r: usize) -> Vec<TripleKey> {
        let mut out = vec![];
        for i in 0..q {
            for j in 0..q {
                for l in 0..rho {
                    let h = (i + j + l + r * rho) % q;
                    out.push(TripleKey::new(i, h, j));
                }
            }
        }
        out
    }

    fn spread(counts: &[usize]) -> (usize, usize) {
        (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        )
    }

    #[test]
    fn figure1_balanced_beats_naive() {
        // Paper Figure 1 configuration: √n=32000, √m=4000 → q=8, ρ=8,
        // round 0, T=64 reduce tasks.
        let (q, rho, t) = (8, 8, 64);
        let keys = round_keys(q, rho, 0);
        assert_eq!(keys.len(), rho * q * q); // 512 reducers

        let mut naive_counts = vec![0usize; t];
        let mut bal_counts = vec![0usize; t];
        let bal = BalancedPartitioner3d { q, rho };
        for k in &keys {
            naive_counts[NaiveTriplePartitioner.partition(k, t)] += 1;
            bal_counts[bal.partition(k, t)] += 1;
        }
        let naive_cv = stats::cv(&naive_counts.iter().map(|&c| c as f64).collect::<Vec<_>>());
        let bal_cv = stats::cv(&bal_counts.iter().map(|&c| c as f64).collect::<Vec<_>>());
        let (bmin, bmax) = spread(&bal_counts);
        // Balanced: every task gets exactly ρq²/T = 8 reducers.
        assert_eq!((bmin, bmax), (8, 8), "balanced should be perfectly even");
        assert!(naive_cv > bal_cv, "naive cv {naive_cv} vs balanced {bal_cv}");
        // Naive is visibly unbalanced (Figure 1 shows tasks with 0 and
        // with >2× the mean).
        let (nmin, nmax) = spread(&naive_counts);
        assert!(nmax > nmin, "naive should be uneven: {naive_counts:?}");
    }

    #[test]
    fn balanced_even_across_rounds() {
        // The rotation h → h+ρ between rounds must not break balance:
        // h mod ρ is round-invariant (ρ | q).
        let (q, rho, t) = (8, 4, 16);
        let bal = BalancedPartitioner3d { q, rho };
        for r in 0..q / rho {
            let mut counts = vec![0usize; t];
            for k in round_keys(q, rho, r) {
                counts[bal.partition(&k, t)] += 1;
            }
            let (min, max) = spread(&counts);
            assert_eq!(min, max, "round {r} counts {counts:?}");
        }
    }

    #[test]
    fn balanced_final_round_even() {
        let (q, rho, t) = (8, 4, 16);
        let bal = BalancedPartitioner3d { q, rho };
        let mut counts = vec![0usize; t];
        for i in 0..q {
            for j in 0..q {
                counts[bal.partition(&TripleKey::io(i, j), t)] += 1;
            }
        }
        let (min, max) = spread(&counts);
        assert_eq!((min, max), (4, 4));
    }

    #[test]
    fn balanced_handles_leftover_keys() {
        // T ∤ ρq²: 512 keys over 60 tasks → B=8, 480 dealt evenly,
        // 32 scattered.
        let (q, rho, t) = (8, 8, 60);
        let bal = BalancedPartitioner3d { q, rho };
        let mut counts = vec![0usize; t];
        for k in round_keys(q, rho, 0) {
            let task = bal.partition(&k, t);
            assert!(task < t);
            counts[task] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 512);
        let (_, max) = spread(&counts);
        assert!(max <= 8 + 4, "no task should be overloaded: {counts:?}");
    }

    #[test]
    fn prop_partitioners_in_range() {
        run_prop("partition in [0,T)", 100, |case| {
            let q = 1 + case.rng.next_usize(16);
            let rho = 1 + case.rng.next_usize(q);
            let t = 1 + case.rng.next_usize(64);
            let bal = BalancedPartitioner3d { q, rho };
            let i = case.rng.next_usize(q);
            let j = case.rng.next_usize(q);
            let h = case.rng.next_usize(q);
            for key in [TripleKey::new(i, h, j), TripleKey::io(i, j)] {
                let v = bal.partition(&key, t);
                if v >= t {
                    return Err(format!("balanced out of range: {v} >= {t}"));
                }
                let v = NaiveTriplePartitioner.partition(&key, t);
                if v >= t {
                    return Err(format!("naive out of range: {v} >= {t}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn balanced_2d_even() {
        // s=8 strips, ρ=2, T=4: round keys (i, (i+l+rρ) mod s).
        let (s, rho, t) = (8, 2, 4);
        let bal = BalancedPartitioner2d { strips: s, rho };
        for r in 0..s / rho {
            let mut counts = vec![0usize; t];
            for i in 0..s {
                for l in 0..rho {
                    let j = (i + l + r * rho) % s;
                    counts[bal.partition(&PairKey::new(i, j), t)] += 1;
                }
            }
            let (min, max) = spread(&counts);
            assert_eq!(min, max, "round {r}: {counts:?}");
        }
    }

    #[test]
    fn balanced_2d_unique_z_within_round() {
        let (s, rho) = (8, 4);
        let bal = BalancedPartitioner2d { strips: s, rho };
        // With T = ρ·s every key must land alone on its task.
        let t = rho * s;
        for r in 0..s / rho {
            let mut seen = vec![false; t];
            for i in 0..s {
                for l in 0..rho {
                    let j = (i + l + r * rho) % s;
                    let task = bal.partition(&PairKey::new(i, j), t);
                    assert!(!seen[task], "collision at round {r} key ({i},{j})");
                    seen[task] = true;
                }
            }
        }
    }

    #[test]
    fn naive_partitioner_handles_negative_dummy() {
        // Keys with h = -1 must still land in range.
        for t in [1, 7, 64] {
            let v = NaiveTriplePartitioner.partition(&TripleKey::io(0, 0), t);
            assert!(v < t);
        }
    }
}
