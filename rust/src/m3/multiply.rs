//! High-level public API of the M3 library: dense/sparse payloads and
//! the `multiply_*` entry points that wire plans, algorithms, engine
//! and backend together.

use std::sync::Arc;

use anyhow::Result;

use crate::mapreduce::types::{Partitioner, Value};
use crate::mapreduce::wire::{ByteReader, CodecHandle, Wire, WireError, WirePairCodec};
use crate::mapreduce::{Driver, EngineConfig, JobMetrics, Pair, TransportSel};
use crate::matrix::semiring::{Arithmetic, Semiring};
use crate::matrix::{BlockGrid, CooMatrix, CsrMatrix, DenseMatrix};
use crate::runtime::{kernels, LocalMultiply};

use super::algo3d::{Algo3d, Block3d, BlockOps, Geometry, Tag};
use super::dense2d::Algo2d;
use super::keys::TripleKey;
use super::partitioner::{
    BalancedPartitioner2d, BalancedPartitioner3d, NaiveTriplePartitioner,
};
use super::planner::{Plan2d, Plan3d, SparsePlan};

/// Which partitioner routes groups to reduce tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionerKind {
    /// Paper Algorithm 3 (default — Figure 1 right).
    #[default]
    Balanced,
    /// The `31²i + 31j + k` hash (Figure 1 left).
    Naive,
}

/// Configuration of an M3 multiplication.
#[derive(Debug, Clone)]
pub struct M3Config {
    /// Block side `√m` (3D) — for 2D, `m = block_side²`.
    pub block_side: usize,
    /// Replication factor ρ.
    pub rho: usize,
    /// Engine (cluster) configuration.
    pub engine: EngineConfig,
    /// Partitioner choice.
    pub partitioner: PartitionerKind,
    /// Shuffle transport: serialized in-process by default, with the
    /// zero-copy `Arc` path and the multi-process backend selectable
    /// (see [`TransportSel`]).
    pub transport: TransportSel,
}

impl M3Config {
    /// A config with the default engine, balanced partitioner and
    /// serialized in-process transport.
    pub fn new(block_side: usize, rho: usize) -> Self {
        Self {
            block_side,
            rho,
            engine: EngineConfig::default(),
            partitioner: PartitionerKind::default(),
            transport: TransportSel::default(),
        }
    }
}

pub(crate) fn make_partitioner_3d(
    kind: PartitionerKind,
    q: usize,
    rho: usize,
) -> Box<dyn Partitioner<TripleKey>> {
    match kind {
        PartitionerKind::Balanced => Box::new(BalancedPartitioner3d { q, rho }),
        PartitionerKind::Naive => Box::new(NaiveTriplePartitioner),
    }
}

// ---------------------------------------------------------------------
// Dense payload
// ---------------------------------------------------------------------

/// Dense block payload for the 3D algorithm.
///
/// Variants hold `Arc<DenseMatrix>` so every payload clone on the
/// engine's hot path — the ρ-way map fan-out, the per-round
/// static-input re-feed, and preemption carry clones — is a pointer
/// bump, never a matrix copy. Ownership rule: blocks are immutable
/// once wrapped; mutation happens only on freshly computed matrices
/// (reducer `fma`/`sum` results) before they are wrapped via
/// [`DenseBlock::a`]/[`b`](DenseBlock::b)/[`c`](DenseBlock::c).
#[derive(Debug, Clone, PartialEq)]
pub enum DenseBlock {
    /// A block of the left matrix.
    A(Arc<DenseMatrix>),
    /// A block of the right matrix.
    B(Arc<DenseMatrix>),
    /// An accumulator block.
    C(Arc<DenseMatrix>),
}

impl DenseBlock {
    /// Wrap a left-matrix block.
    pub fn a(m: DenseMatrix) -> Self {
        DenseBlock::A(Arc::new(m))
    }

    /// Wrap a right-matrix block.
    pub fn b(m: DenseMatrix) -> Self {
        DenseBlock::B(Arc::new(m))
    }

    /// Wrap an accumulator block.
    pub fn c(m: DenseMatrix) -> Self {
        DenseBlock::C(Arc::new(m))
    }

    /// The wrapped matrix.
    pub fn matrix(&self) -> &DenseMatrix {
        match self {
            DenseBlock::A(m) | DenseBlock::B(m) | DenseBlock::C(m) => m,
        }
    }
}

/// Take the matrix out of its `Arc`, copying only if it is still
/// shared (final-round outputs are uniquely owned, so assembling the
/// product is copy-free).
pub(crate) fn unshare<T: Clone>(m: Arc<T>) -> T {
    Arc::try_unwrap(m).unwrap_or_else(|shared| (*shared).clone())
}

impl Value for DenseBlock {
    fn words(&self) -> usize {
        self.matrix().words()
    }
}

impl Block3d for DenseBlock {
    fn tag(&self) -> Tag {
        match self {
            DenseBlock::A(_) => Tag::A,
            DenseBlock::B(_) => Tag::B,
            DenseBlock::C(_) => Tag::C,
        }
    }

    fn wire_codec() -> Option<CodecHandle<TripleKey, Self>> {
        Some(Arc::new(WirePairCodec::default()))
    }
}

/// Variant bytes of block payloads on the wire. The Strassen rounds
/// overload `A`/`B` as the *sign* of a contribution, so the variant is
/// semantic cargo, not a hint — it must survive the wire exactly.
const WIRE_TAG_A: u8 = 0;
const WIRE_TAG_B: u8 = 1;
const WIRE_TAG_C: u8 = 2;

/// Wire form: one variant byte (`0`/`1`/`2` = `A`/`B`/`C`), then the
/// matrix body in its own self-describing encoding.
impl Wire for DenseBlock {
    fn wire_encode(&self, out: &mut Vec<u8>) {
        let (tag, m) = match self {
            DenseBlock::A(m) => (WIRE_TAG_A, m),
            DenseBlock::B(m) => (WIRE_TAG_B, m),
            DenseBlock::C(m) => (WIRE_TAG_C, m),
        };
        out.push(tag);
        m.wire_encode(out);
    }

    fn wire_decode(r: &mut ByteReader) -> Result<Self, WireError> {
        let tag = r.u8()?;
        if tag > WIRE_TAG_C {
            return Err(WireError::Corrupt("unknown dense block variant"));
        }
        let m = Arc::new(DenseMatrix::wire_decode(r)?);
        Ok(match tag {
            WIRE_TAG_A => DenseBlock::A(m),
            WIRE_TAG_B => DenseBlock::B(m),
            _ => DenseBlock::C(m),
        })
    }
}

/// Dense block algebra: FMA through a [`LocalMultiply`] backend (the
/// XLA/Pallas artifact on the hot path), ρ-way sum in plain Rust.
pub struct DenseOps {
    backend: Arc<dyn LocalMultiply>,
}

impl DenseOps {
    /// Wrap a backend.
    pub fn new(backend: Arc<dyn LocalMultiply>) -> Self {
        Self { backend }
    }
}

impl BlockOps<DenseBlock> for DenseOps {
    fn fma(&self, a: &DenseBlock, b: &DenseBlock, c: Option<&DenseBlock>) -> DenseBlock {
        crate::mapreduce::executor::record_block_product();
        let (a, b) = (a.matrix(), b.matrix());
        let out = match c {
            // A carried accumulator is shared (`Arc`), so the backend
            // copies it once into the output.
            Some(c) => self.backend.multiply_acc(a, b, c.matrix()),
            // No carry: accumulate straight into one fresh zero buffer
            // instead of allocating zeros and cloning them.
            None => self
                .backend
                .multiply_acc_into(a, b, DenseMatrix::zeros(a.rows(), b.cols())),
        };
        DenseBlock::c(out)
    }

    fn sum(&self, parts: Vec<DenseBlock>) -> DenseBlock {
        let mut it = parts.into_iter();
        let mut acc = match it.next().expect("sum of zero parts") {
            DenseBlock::C(m) => unshare(m),
            _ => panic!("sum over non-C block"),
        };
        for p in it {
            match p {
                DenseBlock::C(m) => acc.add_assign(&m),
                _ => panic!("sum over non-C block"),
            }
        }
        DenseBlock::c(acc)
    }
}

/// Semiring block algebra: the 3D algorithm over an arbitrary
/// [`Semiring`] (the paper rules out Strassen precisely to keep this
/// generality). `(min,+)` and `(∨,∧)` have no MXU/BLAS form, so the
/// local multiply is the tiled semiring GEMM kernel via its
/// tile-parallel entry point ([`kernels::gemm_acc_sr_par`]) — same
/// `i-k-j` contiguous-row layout as the f32 path, vectorisable `⊕`/`⊗`
/// inner loop, bit-for-bit equal to the naive triple-loop oracle it
/// replaced, and split into stealable row panels when the block is big
/// enough and idle pool workers are available.
pub struct SemiringOps<S: Semiring>(std::marker::PhantomData<S>);

impl<S: Semiring> Default for SemiringOps<S> {
    fn default() -> Self {
        Self(std::marker::PhantomData)
    }
}

impl<S: Semiring> BlockOps<DenseBlock> for SemiringOps<S> {
    fn fma(&self, a: &DenseBlock, b: &DenseBlock, c: Option<&DenseBlock>) -> DenseBlock {
        crate::mapreduce::executor::record_block_product();
        let (am, bm) = (a.matrix(), b.matrix());
        assert_eq!(am.cols(), bm.rows(), "inner dimensions must agree");
        let mut prod = DenseMatrix::filled(am.rows(), bm.cols(), S::zero());
        kernels::gemm_acc_sr_par::<S>(
            am.rows(),
            am.cols(),
            bm.cols(),
            am.as_slice(),
            bm.as_slice(),
            prod.as_mut_slice(),
        );
        if let Some(c) = c {
            // ⊕ is commutative in every semiring here, so accumulate
            // into the fresh product instead of copying `c`.
            prod.add_assign_sr::<S>(c.matrix());
        }
        DenseBlock::c(prod)
    }

    fn sum(&self, parts: Vec<DenseBlock>) -> DenseBlock {
        let mut it = parts.into_iter();
        let mut acc = match it.next().expect("sum of zero parts") {
            DenseBlock::C(m) => unshare(m),
            _ => panic!("sum over non-C block"),
        };
        for p in it {
            match p {
                DenseBlock::C(m) => acc.add_assign_sr::<S>(&m),
                _ => panic!("sum over non-C block"),
            }
        }
        DenseBlock::c(acc)
    }
}

/// Build the 3D static input pairs `⟨(i,-1,j); A|B block⟩` from two
/// dense matrices split on `grid`.
pub fn dense_3d_static_input(
    grid: &BlockGrid,
    a: &DenseMatrix,
    b: &DenseMatrix,
) -> Vec<Pair<TripleKey, DenseBlock>> {
    let mut input: Vec<Pair<TripleKey, DenseBlock>> = Vec::with_capacity(2 * grid.num_blocks());
    for ((i, j), blk) in grid.split(a) {
        input.push(Pair::new(TripleKey::io(i, j), DenseBlock::a(blk)));
    }
    for ((i, j), blk) in grid.split(b) {
        input.push(Pair::new(TripleKey::io(i, j), DenseBlock::b(blk)));
    }
    input
}

/// Assemble the product matrix from the final-round `C` blocks.
pub fn dense_3d_assemble(
    grid: &BlockGrid,
    output: Vec<Pair<TripleKey, DenseBlock>>,
) -> DenseMatrix {
    let blocks: Vec<((usize, usize), DenseMatrix)> = output
        .into_iter()
        .map(|p| {
            assert!(p.key.is_io());
            let m = match p.value {
                DenseBlock::C(m) => unshare(m),
                _ => panic!("final output must be C blocks"),
            };
            ((p.key.i as usize, p.key.j as usize), m)
        })
        .collect();
    grid.assemble(&blocks)
}

/// Shared driver for dense 3D runs over any block algebra.
fn run_dense_3d(
    a: &DenseMatrix,
    b: &DenseMatrix,
    cfg: &M3Config,
    ops: Arc<dyn BlockOps<DenseBlock>>,
) -> Result<(DenseMatrix, JobMetrics)> {
    anyhow::ensure!(a.rows() == a.cols(), "A must be square");
    anyhow::ensure!(b.rows() == b.cols(), "B must be square");
    anyhow::ensure!(a.rows() == b.rows(), "A and B must have the same side");
    let plan = Plan3d::new(a.rows(), cfg.block_side, cfg.rho)?;
    let geo: Geometry = plan.into();
    let grid = BlockGrid::new(plan.side, plan.block_side);
    let input = dense_3d_static_input(&grid, a, b);

    let alg = Algo3d::new(
        geo,
        ops,
        make_partitioner_3d(cfg.partitioner, geo.q, geo.rho),
    );
    let mut driver = Driver::new(cfg.engine);
    driver.set_transport(cfg.transport.clone());
    let res = driver.run(&alg, &input);
    Ok((dense_3d_assemble(&grid, res.output), res.metrics))
}

/// Multiply two dense square matrices with the 3D multi-round
/// algorithm (arithmetic semiring, accelerated `backend` on the
/// reducer hot path). Returns the product and the per-round metrics.
pub fn multiply_dense_3d(
    a: &DenseMatrix,
    b: &DenseMatrix,
    cfg: &M3Config,
    backend: Arc<dyn LocalMultiply>,
) -> Result<(DenseMatrix, JobMetrics)> {
    run_dense_3d(a, b, cfg, Arc::new(DenseOps::new(backend)))
}

/// Multiply two dense square matrices with the 3D algorithm over an
/// arbitrary semiring `S` — `(min,+)` for shortest paths, `(∨,∧)` for
/// reachability, etc.
pub fn multiply_dense_3d_sr<S: Semiring>(
    a: &DenseMatrix,
    b: &DenseMatrix,
    cfg: &M3Config,
) -> Result<(DenseMatrix, JobMetrics)> {
    run_dense_3d(a, b, cfg, Arc::new(SemiringOps::<S>::default()))
}

/// Multiply two dense square matrices with the 2D baseline algorithm
/// (paper Algorithm 2). `cfg.block_side²` is used as the subproblem
/// size `m`.
pub fn multiply_dense_2d(
    a: &DenseMatrix,
    b: &DenseMatrix,
    cfg: &M3Config,
    backend: Arc<dyn LocalMultiply>,
) -> Result<(DenseMatrix, JobMetrics)> {
    anyhow::ensure!(a.rows() == a.cols() && a.rows() == b.rows() && b.rows() == b.cols());
    let m = cfg.block_side * cfg.block_side;
    let plan = Plan2d::new(a.rows(), m, cfg.rho)?;
    let partitioner: Box<dyn Partitioner<super::keys::PairKey>> = match cfg.partitioner {
        PartitionerKind::Balanced | PartitionerKind::Naive => Box::new(BalancedPartitioner2d {
            strips: plan.strips(),
            rho: plan.rho,
        }),
    };
    let alg = Algo2d::new(plan, backend, partitioner);
    let input = Algo2d::static_input(plan, a, b);
    let mut driver = Driver::new(cfg.engine);
    driver.set_transport(cfg.transport.clone());
    let res = driver.run(&alg, &input);
    Ok((Algo2d::assemble_output(plan, &res.output), res.metrics))
}

// ---------------------------------------------------------------------
// Sparse payload
// ---------------------------------------------------------------------

/// Sparse (CSR) block payload for the 3D algorithm. `Arc`-backed for
/// the same zero-copy clone semantics as [`DenseBlock`].
#[derive(Debug, Clone, PartialEq)]
pub enum SparseBlock {
    /// A block of the left matrix.
    A(Arc<CsrMatrix>),
    /// A block of the right matrix.
    B(Arc<CsrMatrix>),
    /// An accumulator block.
    C(Arc<CsrMatrix>),
}

impl SparseBlock {
    /// Wrap a left-matrix block.
    pub fn a(m: CsrMatrix) -> Self {
        SparseBlock::A(Arc::new(m))
    }

    /// Wrap a right-matrix block.
    pub fn b(m: CsrMatrix) -> Self {
        SparseBlock::B(Arc::new(m))
    }

    /// Wrap an accumulator block.
    pub fn c(m: CsrMatrix) -> Self {
        SparseBlock::C(Arc::new(m))
    }

    /// The wrapped CSR block.
    pub fn csr(&self) -> &CsrMatrix {
        match self {
            SparseBlock::A(m) | SparseBlock::B(m) | SparseBlock::C(m) => m,
        }
    }
}

impl Value for SparseBlock {
    fn words(&self) -> usize {
        self.csr().words()
    }
}

impl Block3d for SparseBlock {
    fn tag(&self) -> Tag {
        match self {
            SparseBlock::A(_) => Tag::A,
            SparseBlock::B(_) => Tag::B,
            SparseBlock::C(_) => Tag::C,
        }
    }

    fn wire_codec() -> Option<CodecHandle<TripleKey, Self>> {
        Some(Arc::new(WirePairCodec::default()))
    }
}

/// Wire form: one variant byte, then the CSR body (bitmap/delta column
/// encoding chosen per row inside [`CsrMatrix`]'s codec).
impl Wire for SparseBlock {
    fn wire_encode(&self, out: &mut Vec<u8>) {
        let (tag, m) = match self {
            SparseBlock::A(m) => (WIRE_TAG_A, m),
            SparseBlock::B(m) => (WIRE_TAG_B, m),
            SparseBlock::C(m) => (WIRE_TAG_C, m),
        };
        out.push(tag);
        m.wire_encode(out);
    }

    fn wire_decode(r: &mut ByteReader) -> Result<Self, WireError> {
        let tag = r.u8()?;
        if tag > WIRE_TAG_C {
            return Err(WireError::Corrupt("unknown sparse block variant"));
        }
        let m = Arc::new(CsrMatrix::wire_decode(r)?);
        Ok(match tag {
            WIRE_TAG_A => SparseBlock::A(m),
            WIRE_TAG_B => SparseBlock::B(m),
            _ => SparseBlock::C(m),
        })
    }
}

/// Sparse block algebra: epoch-marked Gustavson SpGEMM (with stealable
/// row panels for oversized blocks — `CsrMatrix::spgemm_par`),
/// two-pointer merged-row add, and a k-way sorted-row merge for the
/// ρ-way sum (the role MTJ played in the paper's implementation).
pub struct SparseOps;

impl BlockOps<SparseBlock> for SparseOps {
    fn fma(&self, a: &SparseBlock, b: &SparseBlock, c: Option<&SparseBlock>) -> SparseBlock {
        crate::mapreduce::executor::record_block_product();
        let prod = a.csr().spgemm_par(b.csr());
        let out = match c {
            Some(c) => c.csr().add(&prod),
            None => prod,
        };
        SparseBlock::c(out)
    }

    fn sum(&self, parts: Vec<SparseBlock>) -> SparseBlock {
        if parts.len() == 1 {
            let only = parts.into_iter().next().expect("sum of zero parts");
            assert!(matches!(only, SparseBlock::C(_)), "sum over non-C block");
            return only;
        }
        // All parts' rows are already column-sorted, so one k-way merge
        // replaces the old pairwise COO-round-trip adds.
        let csrs: Vec<&CsrMatrix> = parts
            .iter()
            .map(|p| match p {
                SparseBlock::C(m) => m.as_ref(),
                _ => panic!("sum over non-C block"),
            })
            .collect();
        SparseBlock::c(CsrMatrix::sum_sr::<Arithmetic>(&csrs))
    }
}

/// Build the 3D static input pairs for the sparse algorithm: each
/// `block_side`-square block of `a`/`b` converted to CSR.
pub fn sparse_3d_static_input(
    block_side: usize,
    a: &CooMatrix,
    b: &CooMatrix,
) -> Vec<Pair<TripleKey, SparseBlock>> {
    let mut input: Vec<Pair<TripleKey, SparseBlock>> = vec![];
    for ((i, j), blk) in a.split_blocks(block_side, block_side) {
        input.push(Pair::new(TripleKey::io(i, j), SparseBlock::a(blk.to_csr())));
    }
    for ((i, j), blk) in b.split_blocks(block_side, block_side) {
        input.push(Pair::new(TripleKey::io(i, j), SparseBlock::b(blk.to_csr())));
    }
    input
}

/// Reassemble the sparse product: offset each final `C` block's entries
/// by its block origin.
pub fn sparse_3d_assemble(
    side: usize,
    block_side: usize,
    output: Vec<Pair<TripleKey, SparseBlock>>,
) -> CooMatrix {
    let bs = block_side;
    let mut out = CooMatrix::new(side, side);
    for p in output {
        assert!(p.key.is_io());
        let (bi, bj) = (p.key.i as usize, p.key.j as usize);
        let csr = match p.value {
            SparseBlock::C(m) => m,
            _ => panic!("final output must be C blocks"),
        };
        for (r, row) in (0..csr.rows()).map(|r| (r, csr.row(r))) {
            for (c, v) in row {
                if v != 0.0 {
                    out.push(bi * bs + r, bj * bs + c, v);
                }
            }
        }
    }
    out
}

/// Multiply two sparse square matrices with the 3D multi-round sparse
/// algorithm (paper §3.2). `plan` fixes the sparse block side
/// `√m' = √(m/δ_M)`.
pub fn multiply_sparse_3d(
    a: &CooMatrix,
    b: &CooMatrix,
    plan: &SparsePlan,
    engine: EngineConfig,
    partitioner: PartitionerKind,
    transport: TransportSel,
) -> Result<(CooMatrix, JobMetrics)> {
    anyhow::ensure!(a.rows() == a.cols(), "A must be square");
    anyhow::ensure!(b.rows() == b.cols() && a.rows() == b.rows());
    anyhow::ensure!(a.rows() == plan.side, "plan side mismatch");
    let geo = Geometry {
        q: plan.q(),
        rho: plan.rho,
    };

    let input = sparse_3d_static_input(plan.block_side, a, b);
    let alg = Algo3d::new(
        geo,
        Arc::new(SparseOps),
        make_partitioner_3d(partitioner, geo.q, geo.rho),
    );
    let mut driver = Driver::new(engine);
    driver.set_transport(transport);
    let res = driver.run(&alg, &input);
    Ok((
        sparse_3d_assemble(plan.side, plan.block_side, res.output),
        res.metrics,
    ))
}

/// The paper's §3.2 *general* sparse flow: estimate the output density
/// with one scan (Pagh–Stöckel-style degree products), randomly permute
/// rows/columns for block load balance, size blocks by
/// `m' = m/δ_M`, run the 3D sparse algorithm, and un-permute the
/// output. `m` is the reducer memory budget in words.
pub fn multiply_sparse_3d_general(
    a: &CooMatrix,
    b: &CooMatrix,
    m: usize,
    rho: usize,
    engine: EngineConfig,
    seed: u64,
) -> Result<(CooMatrix, JobMetrics)> {
    use super::sparse_tools::{estimate_output_density, ProductPermutation};
    use crate::util::rng::Xoshiro256ss;
    anyhow::ensure!(a.rows() == a.cols() && b.rows() == b.cols() && a.rows() == b.rows());
    let side = a.rows();
    let delta = (a.density().max(b.density())).max(1.0 / (side as f64 * side as f64));
    let delta_o = estimate_output_density(a, b);
    let mut plan = SparsePlan::from_memory_budget(side, m, delta, delta_o, rho)?;
    // from_memory_budget clips the block side; re-validate ρ | q.
    while plan.q() % plan.rho != 0 {
        plan = SparsePlan::new(side, plan.block_side / 2, rho, delta, plan.delta_m)?;
    }
    let mut rng = Xoshiro256ss::new(seed);
    let perm = ProductPermutation::random(side, &mut rng);
    let (c_perm, metrics) = multiply_sparse_3d(
        &perm.apply_left(a),
        &perm.apply_right(b),
        &plan,
        engine,
        PartitionerKind::Balanced,
        TransportSel::default(),
    )?;
    Ok((perm.unapply_output(&c_perm), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::runtime::native::NativeMultiply;
    use crate::runtime::NaiveMultiply;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    fn engine() -> EngineConfig {
        EngineConfig {
            map_tasks: 4,
            reduce_tasks: 4,
            workers: 4,
        }
    }

    fn cfg(block_side: usize, rho: usize) -> M3Config {
        M3Config {
            block_side,
            rho,
            engine: engine(),
            partitioner: PartitionerKind::Balanced,
            transport: TransportSel::default(),
        }
    }

    #[test]
    fn dense_3d_matches_naive_all_rhos() {
        let side = 24;
        let mut rng = Xoshiro256ss::new(1);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let want = a.matmul_naive(&b);
        for rho in [1, 2, 3, 6] {
            let (got, metrics) =
                multiply_dense_3d(&a, &b, &cfg(4, rho), Arc::new(NativeMultiply::new())).unwrap();
            assert_eq!(got, want, "rho={rho}");
            assert_eq!(metrics.num_rounds(), 6 / rho + 1);
        }
    }

    #[test]
    fn dense_3d_with_naive_partitioner() {
        let side = 16;
        let mut rng = Xoshiro256ss::new(2);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let mut c = cfg(4, 2);
        c.partitioner = PartitionerKind::Naive;
        let (got, _) = multiply_dense_3d(&a, &b, &c, Arc::new(NaiveMultiply)).unwrap();
        assert_eq!(got, a.matmul_naive(&b));
    }

    #[test]
    fn dense_3d_theorem_bounds() {
        let side = 32;
        let mut rng = Xoshiro256ss::new(3);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let config = cfg(8, 2);
        let plan = Plan3d::new(side, 8, 2).unwrap();
        let (_, metrics) =
            multiply_dense_3d(&a, &b, &config, Arc::new(NativeMultiply::new())).unwrap();
        assert_eq!(metrics.num_rounds(), plan.rounds());
        for r in &metrics.rounds {
            assert!(
                r.shuffle_words <= plan.shuffle_words_bound(),
                "round {}: shuffle {} > 3ρn {}",
                r.round,
                r.shuffle_words,
                plan.shuffle_words_bound()
            );
            assert!(
                r.max_reducer_words <= plan.reducer_words_bound(),
                "round {}: reducer {} > 3m",
                r.round,
                r.max_reducer_words
            );
        }
    }

    #[test]
    fn dense_3d_rejects_invalid_config() {
        let a = DenseMatrix::zeros(16, 16);
        let b = DenseMatrix::zeros(16, 16);
        assert!(multiply_dense_3d(&a, &b, &cfg(5, 1), Arc::new(NaiveMultiply)).is_err());
        assert!(multiply_dense_3d(&a, &b, &cfg(4, 3), Arc::new(NaiveMultiply)).is_err());
        let rect = DenseMatrix::zeros(16, 8);
        assert!(multiply_dense_3d(&rect, &b, &cfg(4, 1), Arc::new(NaiveMultiply)).is_err());
    }

    #[test]
    fn dense_2d_matches_naive() {
        let side = 16;
        let mut rng = Xoshiro256ss::new(4);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let want = a.matmul_naive(&b);
        for rho in [1, 2, 4] {
            let (got, _) =
                multiply_dense_2d(&a, &b, &cfg(8, rho), Arc::new(NativeMultiply::new())).unwrap();
            assert_eq!(got, want, "rho={rho}");
        }
    }

    #[test]
    fn dense_2d_vs_3d_shuffle_totals() {
        // Q5/Figure 6: with equal m and ρ=1, 2D shuffles more in total.
        let side = 32;
        let mut rng = Xoshiro256ss::new(5);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let (_, m3d) =
            multiply_dense_3d(&a, &b, &cfg(8, 1), Arc::new(NativeMultiply::new())).unwrap();
        let (_, m2d) =
            multiply_dense_2d(&a, &b, &cfg(8, 1), Arc::new(NativeMultiply::new())).unwrap();
        assert!(
            m2d.total_shuffle_words() > m3d.total_shuffle_words(),
            "2D {} !> 3D {}",
            m2d.total_shuffle_words(),
            m3d.total_shuffle_words()
        );
    }

    #[test]
    fn sparse_3d_matches_dense_reference() {
        let side = 64;
        let mut rng = Xoshiro256ss::new(6);
        let a = gen::erdos_renyi_coo(side, 0.08, &mut rng);
        let b = gen::erdos_renyi_coo(side, 0.08, &mut rng);
        let want = a.to_dense().matmul_naive(&b.to_dense());
        for rho in [1, 2, 4] {
            let plan = SparsePlan::new(side, 16, rho, 0.08, 0.3).unwrap();
            let (got, metrics) = multiply_sparse_3d(
                &a,
                &b,
                &plan,
                engine(),
                PartitionerKind::Balanced,
                TransportSel::default(),
            )
            .unwrap();
            assert_eq!(got.to_dense().max_abs_diff(&want), 0.0, "rho={rho}");
            assert_eq!(metrics.num_rounds(), plan.rounds());
        }
    }

    #[test]
    fn sparse_general_flow_exact() {
        // The full §3.2 pipeline: estimate, permute, multiply, restore.
        let side = 128;
        let mut rng = Xoshiro256ss::new(20);
        let a = gen::erdos_renyi_coo(side, 0.06, &mut rng);
        let b = gen::erdos_renyi_coo(side, 0.06, &mut rng);
        let want = a.to_csr().spgemm(&b.to_csr()).to_dense();
        for rho in [1usize, 2] {
            let (got, _) =
                multiply_sparse_3d_general(&a, &b, 4096, rho, engine(), 77).unwrap();
            assert_eq!(got.to_dense().max_abs_diff(&want), 0.0, "rho={rho}");
        }
    }

    #[test]
    fn sparse_general_flow_clustered_input() {
        // Clustered nnz (all in one corner) — the permutation is what
        // keeps blocks balanced; the result must still be exact.
        let side = 64;
        let mut rng = Xoshiro256ss::new(21);
        let mut a = CooMatrix::new(side, side);
        for _ in 0..300 {
            a.push(rng.next_usize(12), rng.next_usize(12), rng.small_int_f32());
        }
        let b = gen::erdos_renyi_coo(side, 0.1, &mut rng);
        let want = a.to_csr().spgemm(&b.to_csr()).to_dense();
        let (got, _) = multiply_sparse_3d_general(&a, &b, 1024, 1, engine(), 5).unwrap();
        assert_eq!(got.to_dense().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn sparse_3d_empty_inputs() {
        let side = 32;
        let a = CooMatrix::new(side, side);
        let b = CooMatrix::new(side, side);
        let plan = SparsePlan::new(side, 8, 2, 0.01, 0.01).unwrap();
        let (got, _) = multiply_sparse_3d(
            &a,
            &b,
            &plan,
            engine(),
            PartitionerKind::Balanced,
            TransportSel::default(),
        )
        .unwrap();
        assert_eq!(got.nnz(), 0);
    }

    #[test]
    fn prop_dense_3d_random_geometries() {
        run_prop("dense 3d multiply", 6, |case| {
            let bs = 1 + case.rng.next_usize(4); // block side 1..=4
            let q = 2 + case.rng.next_usize(4); // q 2..=5
            let side = bs * q;
            let divisors: Vec<usize> = (1..=q).filter(|d| q % d == 0).collect();
            let rho = divisors[case.rng.next_usize(divisors.len())];
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::dense_int(side, side, &mut rng);
            let b = gen::dense_int(side, side, &mut rng);
            let (got, _) = multiply_dense_3d(
                &a,
                &b,
                &cfg(bs, rho),
                Arc::new(NativeMultiply::new()),
            )
            .map_err(|e| e.to_string())?;
            if got != a.matmul_naive(&b) {
                return Err(format!("mismatch side={side} bs={bs} rho={rho}"));
            }
            Ok(())
        });
    }

    #[test]
    fn minplus_3d_computes_two_hop_distances() {
        use crate::matrix::semiring::MinPlus;
        // Random weighted digraph as a distance matrix; A⊗A in (min,+)
        // is the ≤2-hop shortest-path matrix.
        let side = 16;
        let mut rng = Xoshiro256ss::new(7);
        let dist = DenseMatrix::from_fn(side, side, |i, j| {
            if i == j {
                0.0
            } else if rng.bernoulli(0.3) {
                rng.range_u64(1, 9) as f32
            } else {
                f32::INFINITY
            }
        });
        let want = dist.matmul_naive_sr::<MinPlus>(&dist);
        for rho in [1usize, 2, 4] {
            let (got, _) = multiply_dense_3d_sr::<MinPlus>(&dist, &dist, &cfg(4, rho)).unwrap();
            assert_eq!(got, want, "rho={rho}");
        }
    }

    #[test]
    fn boolean_3d_computes_reachability() {
        use crate::matrix::semiring::BoolOrAnd;
        let side = 12;
        let mut rng = Xoshiro256ss::new(8);
        let adj = DenseMatrix::from_fn(side, side, |_, _| {
            if rng.bernoulli(0.2) {
                1.0
            } else {
                0.0
            }
        });
        let want = adj.matmul_naive_sr::<BoolOrAnd>(&adj);
        let (got, _) = multiply_dense_3d_sr::<BoolOrAnd>(&adj, &adj, &cfg(4, 3)).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn semiring_arithmetic_agrees_with_backend_path() {
        use crate::matrix::semiring::Arithmetic;
        let side = 24;
        let mut rng = Xoshiro256ss::new(9);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let (via_backend, _) =
            multiply_dense_3d(&a, &b, &cfg(8, 1), Arc::new(NativeMultiply::new())).unwrap();
        let (via_semiring, _) = multiply_dense_3d_sr::<Arithmetic>(&a, &b, &cfg(8, 1)).unwrap();
        assert_eq!(via_backend, via_semiring);
    }

    #[test]
    fn identity_times_identity() {
        let side = 8;
        let a = DenseMatrix::identity(side);
        let (got, _) =
            multiply_dense_3d(&a, &a, &cfg(2, 2), Arc::new(NaiveMultiply)).unwrap();
        assert_eq!(got, DenseMatrix::identity(side));
    }

    #[test]
    fn dense_block_clone_is_zero_copy() {
        // Every engine-side payload clone (ρ-way fan-out, static-input
        // re-feed, carry clones) must be an Arc bump, never a matrix
        // copy: cloning bumps the strong count of the *same* storage.
        let m = Arc::new(DenseMatrix::zeros(32, 32));
        let blk = DenseBlock::A(m.clone());
        assert_eq!(Arc::strong_count(&m), 2);
        let c1 = blk.clone();
        let c2 = blk.clone();
        assert_eq!(Arc::strong_count(&m), 4, "clones share storage");
        assert!(std::ptr::eq(blk.matrix(), c1.matrix()), "no new allocation");
        drop((c1, c2));
        assert_eq!(Arc::strong_count(&m), 2);
    }

    #[test]
    fn sparse_block_clone_is_zero_copy() {
        let csr = Arc::new(CooMatrix::new(8, 8).to_csr());
        let blk = SparseBlock::B(csr.clone());
        let c1 = blk.clone();
        assert_eq!(Arc::strong_count(&csr), 3, "clones share storage");
        assert!(std::ptr::eq(blk.csr(), c1.csr()));
    }

    #[test]
    fn block_wire_roundtrips_preserve_the_variant() {
        // The variant byte is semantic cargo (Strassen signs ride it),
        // so every variant must survive encode∘decode exactly.
        let m = gen::dense_int(5, 7, &mut Xoshiro256ss::new(40));
        for blk in [
            DenseBlock::a(m.clone()),
            DenseBlock::b(m.clone()),
            DenseBlock::c(m.clone()),
        ] {
            let mut buf = Vec::new();
            blk.wire_encode(&mut buf);
            let mut r = ByteReader::new(&buf);
            let back = DenseBlock::wire_decode(&mut r).unwrap();
            assert!(r.is_empty(), "decode must consume the whole body");
            assert_eq!(back, blk);
        }
        let csr = gen::erdos_renyi_coo(9, 0.3, &mut Xoshiro256ss::new(41)).to_csr();
        for blk in [
            SparseBlock::a(csr.clone()),
            SparseBlock::b(csr.clone()),
            SparseBlock::c(csr.clone()),
        ] {
            let mut buf = Vec::new();
            blk.wire_encode(&mut buf);
            let mut r = ByteReader::new(&buf);
            let back = SparseBlock::wire_decode(&mut r).unwrap();
            assert!(r.is_empty());
            assert_eq!(back, blk);
        }
    }

    #[test]
    fn block_wire_rejects_unknown_variants_and_truncation() {
        let blk = DenseBlock::a(DenseMatrix::identity(3));
        let mut buf = Vec::new();
        blk.wire_encode(&mut buf);
        buf[0] = 7; // forge an unknown variant byte
        assert!(DenseBlock::wire_decode(&mut ByteReader::new(&buf)).is_err());
        assert!(DenseBlock::wire_decode(&mut ByteReader::new(&[])).is_err());
        let sblk = SparseBlock::c(CooMatrix::new(2, 2).to_csr());
        let mut sbuf = Vec::new();
        sblk.wire_encode(&mut sbuf);
        sbuf[0] = 0xff;
        assert!(SparseBlock::wire_decode(&mut ByteReader::new(&sbuf)).is_err());
    }

    #[test]
    fn dense_3d_is_bit_identical_across_all_transports() {
        use crate::mapreduce::ProcTransport;
        let side = 16;
        let mut rng = Xoshiro256ss::new(50);
        let a = gen::dense_uniform(side, side, &mut rng);
        let b = gen::dense_uniform(side, side, &mut rng);
        let mut zc = cfg(4, 2);
        zc.transport = TransportSel::ZeroCopy;
        let (want, wm) =
            multiply_dense_3d(&a, &b, &zc, Arc::new(NativeMultiply::new())).unwrap();
        assert_eq!(wm.total_shuffle_bytes(), 0, "zero-copy moves no bytes");

        let ser = cfg(4, 2); // serialized in-proc is the default
        let (got, sm) =
            multiply_dense_3d(&a, &b, &ser, Arc::new(NativeMultiply::new())).unwrap();
        assert_eq!(got.as_slice(), want.as_slice(), "inproc serialized");
        assert!(sm.total_shuffle_bytes() > 0, "serialized path measures bytes");
        assert_eq!(
            sm.total_shuffle_words(),
            wm.total_shuffle_words(),
            "word ledger is transport-invariant"
        );

        let mut pc = cfg(4, 2);
        pc.transport = TransportSel::Proc(ProcTransport::local_threads(2).unwrap());
        let (gotp, pm) =
            multiply_dense_3d(&a, &b, &pc, Arc::new(NativeMultiply::new())).unwrap();
        assert_eq!(gotp.as_slice(), want.as_slice(), "proc transport");
        assert!(pm.total_shuffle_bytes() > 0);
        assert_eq!(pm.total_transport_respawns(), 0);
    }

    #[test]
    fn sparse_3d_is_bit_identical_on_the_serialized_transport() {
        let side = 32;
        let mut rng = Xoshiro256ss::new(51);
        let a = gen::erdos_renyi_coo(side, 0.1, &mut rng);
        let b = gen::erdos_renyi_coo(side, 0.1, &mut rng);
        let plan = SparsePlan::new(side, 8, 2, 0.1, 0.3).unwrap();
        let (want, wm) = multiply_sparse_3d(
            &a,
            &b,
            &plan,
            engine(),
            PartitionerKind::Balanced,
            TransportSel::ZeroCopy,
        )
        .unwrap();
        let (got, sm) = multiply_sparse_3d(
            &a,
            &b,
            &plan,
            engine(),
            PartitionerKind::Balanced,
            TransportSel::default(),
        )
        .unwrap();
        assert_eq!(got.to_dense(), want.to_dense());
        assert_eq!(wm.total_shuffle_bytes(), 0);
        assert!(sm.total_shuffle_bytes() > 0);
        assert_eq!(sm.total_shuffle_words(), wm.total_shuffle_words());
    }

    #[test]
    fn unshare_is_move_when_unique() {
        // Final-round outputs are uniquely owned, so assembling the
        // product takes the matrix without copying.
        let m = DenseMatrix::identity(4);
        let data_ptr = m.as_slice().as_ptr();
        let arc = Arc::new(m);
        let back = unshare(arc);
        assert_eq!(back.as_slice().as_ptr(), data_ptr, "moved, not copied");
    }
}
