//! Sparse preprocessing tools from the paper's §3.2:
//!
//! * **Random row/column permutation** — "For improving the load
//!   balancing among reducers, columns and rows of the input matrices
//!   should be randomly permuted" (general sparse inputs whose nnz are
//!   clustered would overload some blocks).
//! * **Output-density estimation** — the general sparse plan needs an
//!   estimate `δ̃_O` of the product's density ("a good approximation of
//!   the output [density] can be computed with a scan of the input
//!   matrices", citing Pagh–Stöckel). We implement the standard
//!   row/column-degree estimator: `E[nnz(AB)] ≤ Σ_k r_k·c_k` where
//!   `r_k` = nnz of A's column k and `c_k` = nnz of B's row k, with a
//!   birthday-style collision correction for dense outputs.

use crate::matrix::CooMatrix;
use crate::util::rng::Xoshiro256ss;

/// A row/column permutation pair applied to both operands consistently:
/// `A' = P·A·Q`, `B' = Qᵀ·B·R` so that `A'·B' = P·(A·B)·R` — the
/// product of the permuted inputs is the permuted product.
#[derive(Debug, Clone)]
pub struct ProductPermutation {
    /// Row permutation `P` of A (and of the output).
    pub p: Vec<usize>,
    /// Inner permutation `Q` (columns of A / rows of B).
    pub q: Vec<usize>,
    /// Column permutation `R` of B (and of the output).
    pub r: Vec<usize>,
}

impl ProductPermutation {
    /// Sample uniform permutations for a `side × side` product.
    pub fn random(side: usize, rng: &mut Xoshiro256ss) -> Self {
        Self {
            p: rng.permutation(side),
            q: rng.permutation(side),
            r: rng.permutation(side),
        }
    }

    /// Apply to the left operand: `A' [p(i), q(j)] = A[i, j]`.
    pub fn apply_left(&self, a: &CooMatrix) -> CooMatrix {
        let mut out = CooMatrix::new(a.rows(), a.cols());
        for &(i, j, v) in a.entries() {
            out.push(self.p[i as usize], self.q[j as usize], v);
        }
        out
    }

    /// Apply to the right operand: `B'[q(i), r(j)] = B[i, j]`.
    pub fn apply_right(&self, b: &CooMatrix) -> CooMatrix {
        let mut out = CooMatrix::new(b.rows(), b.cols());
        for &(i, j, v) in b.entries() {
            out.push(self.q[i as usize], self.r[j as usize], v);
        }
        out
    }

    /// Undo the output permutation: `C[i, j] = C'[p(i), r(j)]`.
    pub fn unapply_output(&self, c_perm: &CooMatrix) -> CooMatrix {
        let mut p_inv = vec![0usize; self.p.len()];
        for (i, &pi) in self.p.iter().enumerate() {
            p_inv[pi] = i;
        }
        let mut r_inv = vec![0usize; self.r.len()];
        for (j, &rj) in self.r.iter().enumerate() {
            r_inv[rj] = j;
        }
        let mut out = CooMatrix::new(c_perm.rows(), c_perm.cols());
        for &(i, j, v) in c_perm.entries() {
            out.push(p_inv[i as usize], r_inv[j as usize], v);
        }
        out
    }
}

/// Estimate the density of `A·B` with one scan of each input
/// (degree-product bound with a collision correction):
/// `E[nnz] ≈ n_out·(1 − exp(−Σ_k r_k c_k / n_out))`.
pub fn estimate_output_density(a: &CooMatrix, b: &CooMatrix) -> f64 {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must agree");
    let mut a_col_nnz = vec![0u64; a.cols()];
    for &(_, j, _) in a.entries() {
        a_col_nnz[j as usize] += 1;
    }
    let mut b_row_nnz = vec![0u64; b.rows()];
    for &(i, _, _) in b.entries() {
        b_row_nnz[i as usize] += 1;
    }
    let products: f64 = a_col_nnz
        .iter()
        .zip(&b_row_nnz)
        .map(|(&r, &c)| r as f64 * c as f64)
        .sum();
    let cells = a.rows() as f64 * b.cols() as f64;
    if cells == 0.0 {
        return 0.0;
    }
    // Collision-corrected occupancy of the output cells.
    1.0 - (-products / cells).exp()
}

/// Per-block nnz imbalance of a `q × q` blocking: max/mean block nnz.
/// The permutation should drive this toward 1 for clustered inputs.
pub fn block_imbalance(m: &CooMatrix, block_side: usize) -> f64 {
    let blocks = m.split_blocks(block_side, block_side);
    let counts: Vec<f64> = blocks.iter().map(|(_, b)| b.nnz() as f64).collect();
    let mean = counts.iter().sum::<f64>() / counts.len() as f64;
    let max = counts.iter().cloned().fold(0.0, f64::max);
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::run_prop;

    #[test]
    fn permuted_product_unpermutes_to_original() {
        let side = 48;
        let mut rng = Xoshiro256ss::new(1);
        let a = gen::erdos_renyi_coo(side, 0.08, &mut rng);
        let b = gen::erdos_renyi_coo(side, 0.08, &mut rng);
        let want = a.to_csr().spgemm(&b.to_csr()).to_dense();

        let perm = ProductPermutation::random(side, &mut rng);
        let ap = perm.apply_left(&a);
        let bp = perm.apply_right(&b);
        let cp = ap.to_csr().spgemm(&bp.to_csr()).to_coo();
        let c = perm.unapply_output(&cp);
        assert_eq!(c.to_dense().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn prop_permutation_roundtrip_any_seed() {
        run_prop("permute/unpermute", 10, |case| {
            let side = 8 * (1 + case.size(0, 3));
            let mut rng = Xoshiro256ss::new(case.rng.next_u64());
            let a = gen::erdos_renyi_coo(side, 0.1, &mut rng);
            let b = gen::erdos_renyi_coo(side, 0.1, &mut rng);
            let want = a.to_csr().spgemm(&b.to_csr()).to_dense();
            let perm = ProductPermutation::random(side, &mut rng);
            let cp = perm
                .apply_left(&a)
                .to_csr()
                .spgemm(&perm.apply_right(&b).to_csr())
                .to_coo();
            let got = perm.unapply_output(&cp).to_dense();
            if got.max_abs_diff(&want) != 0.0 {
                return Err(format!("mismatch at side={side}"));
            }
            Ok(())
        });
    }

    #[test]
    fn permutation_fixes_clustered_imbalance() {
        // All nnz concentrated in the top-left block.
        let side = 64;
        let mut m = CooMatrix::new(side, side);
        let mut rng = Xoshiro256ss::new(2);
        for _ in 0..400 {
            m.push(rng.next_usize(16), rng.next_usize(16), 1.0);
        }
        let before = block_imbalance(&m, 16);
        assert!(before > 10.0, "clustered input should be imbalanced: {before}");
        let perm = ProductPermutation::random(side, &mut rng);
        let after = block_imbalance(&perm.apply_left(&m), 16);
        assert!(
            after < before / 3.0,
            "permutation should spread the mass: {after} vs {before}"
        );
    }

    #[test]
    fn density_estimate_er_matches_formula() {
        // ER inputs: estimator should land near δ²·side.
        let side = 1024;
        let delta = 16.0 / side as f64;
        let mut rng = Xoshiro256ss::new(3);
        let a = gen::erdos_renyi_coo(side, delta, &mut rng);
        let b = gen::erdos_renyi_coo(side, delta, &mut rng);
        let est = estimate_output_density(&a, &b);
        let formula = gen::er_output_density(side, delta);
        assert!(
            (est - formula).abs() / formula < 0.2,
            "estimate {est:.3e} vs formula {formula:.3e}"
        );
        // And both should be near the measured truth.
        let truth = a.to_csr().spgemm(&b.to_csr()).to_coo().density();
        assert!((est - truth).abs() / truth < 0.25, "est {est:.3e} vs true {truth:.3e}");
    }

    #[test]
    fn density_estimate_empty_and_full() {
        let e = CooMatrix::new(16, 16);
        assert_eq!(estimate_output_density(&e, &e), 0.0);
        let mut rng = Xoshiro256ss::new(4);
        let f = gen::erdos_renyi_coo(16, 1.0, &mut rng);
        let d = estimate_output_density(&f, &f);
        assert!(d > 0.99, "full×full should be ~dense: {d}");
    }
}
