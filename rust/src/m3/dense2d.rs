//! The 2D algorithm (paper Algorithm 2) — the baseline the 3D approach
//! is compared against in Figure 6.
//!
//! A is split into `s = n/m` row strips `A_i` of shape `m/√n × √n`, B
//! into `s` column strips `B_j` of shape `√n × m/√n`; output block
//! `C[i,j] = A_i · B_j` is computed by a single reducer. Round `r`
//! computes the subproblems `(i, j)` with `j = (i + ℓ + rρ) mod s`,
//! `0 ≤ ℓ < ρ`; rounds are independent (no accumulators carried), so
//! every round's reduce output is final.

use std::sync::Arc;

use crate::mapreduce::driver::MultiRoundAlgorithm;
use crate::mapreduce::types::{Mapper, Partitioner, Reducer, Value};
use crate::matrix::DenseMatrix;
use crate::runtime::LocalMultiply;

use super::keys::{umod, PairKey};
use super::planner::Plan2d;

/// A 2D payload: an input strip or an output block. `Arc`-backed so
/// the ρ-way map fan-out and per-round static-input re-feed clone
/// pointers, not strip storage (same ownership rules as
/// [`crate::m3::multiply::DenseBlock`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Strip {
    /// Row strip `A_i`, shape `m/√n × √n`.
    A(Arc<DenseMatrix>),
    /// Column strip `B_j`, shape `√n × m/√n`.
    B(Arc<DenseMatrix>),
    /// Output block `C[i,j]`, shape `m/√n × m/√n`.
    C(Arc<DenseMatrix>),
}

impl Strip {
    /// Wrap a row strip of `A`.
    pub fn a(m: DenseMatrix) -> Self {
        Strip::A(Arc::new(m))
    }

    /// Wrap a column strip of `B`.
    pub fn b(m: DenseMatrix) -> Self {
        Strip::B(Arc::new(m))
    }

    /// Wrap an output block.
    pub fn c(m: DenseMatrix) -> Self {
        Strip::C(Arc::new(m))
    }
}

impl Value for Strip {
    fn words(&self) -> usize {
        match self {
            Strip::A(m) | Strip::B(m) | Strip::C(m) => m.words(),
        }
    }
}

/// Map function of Algorithm 2.
pub struct Mapper2d {
    plan: Plan2d,
}

impl Mapper<PairKey, Strip> for Mapper2d {
    fn map(&self, round: usize, key: &PairKey, value: &Strip, emit: &mut dyn FnMut(PairKey, Strip)) {
        let s = self.plan.strips();
        let rho = self.plan.rho;
        match value {
            Strip::A(_) => {
                let i = key.i as usize;
                for l in 0..rho {
                    let j = (i + l + round * rho) % s;
                    emit(PairKey::new(i, j), value.clone());
                }
            }
            Strip::B(_) => {
                let j = key.j as usize;
                for l in 0..rho {
                    let i = umod(j as isize - l as isize - (round * rho) as isize, s);
                    emit(PairKey::new(i, j), value.clone());
                }
            }
            Strip::C(_) => {
                // C strips are final output; they are never re-mapped
                // (the driver does not carry them).
                unreachable!("C blocks must not re-enter the 2D pipeline");
            }
        }
    }
}

/// Reduce function of Algorithm 2: `C[i,j] = A_i · B_j`.
pub struct Reducer2d {
    plan: Plan2d,
    backend: Arc<dyn LocalMultiply>,
}

impl Reducer<PairKey, Strip> for Reducer2d {
    fn reduce(
        &self,
        round: usize,
        key: &PairKey,
        values: Vec<Strip>,
        emit: &mut dyn FnMut(PairKey, Strip),
    ) {
        let s = self.plan.strips();
        let rho = self.plan.rho;
        // Liveness check: ℓ = (j - i - rρ) mod s must be < ρ.
        let l = umod(
            key.j as isize - key.i as isize - (round * rho) as isize,
            s,
        );
        debug_assert!(l < rho, "2D reducer key {key:?} not live in round {round}");
        let mut a = None;
        let mut b = None;
        for v in values {
            match v {
                Strip::A(m) => {
                    assert!(a.is_none(), "duplicate A strip at {key:?}");
                    a = Some(m);
                }
                Strip::B(m) => {
                    assert!(b.is_none(), "duplicate B strip at {key:?}");
                    b = Some(m);
                }
                Strip::C(_) => panic!("unexpected C at 2D reducer {key:?}"),
            }
        }
        let a = a.unwrap_or_else(|| panic!("missing A strip at {key:?}"));
        let b = b.unwrap_or_else(|| panic!("missing B strip at {key:?}"));
        // The 2D reducer never carries an accumulator, so the product
        // is written straight into one fresh zero buffer.
        let c = self
            .backend
            .multiply_acc_into(&a, &b, DenseMatrix::zeros(a.rows(), b.cols()));
        emit(*key, Strip::c(c));
    }
}

/// The full 2D algorithm.
pub struct Algo2d {
    plan: Plan2d,
    mapper: Mapper2d,
    reducer: Reducer2d,
    partitioner: Box<dyn Partitioner<PairKey>>,
}

impl Algo2d {
    /// Assemble the 2D algorithm.
    pub fn new(
        plan: Plan2d,
        backend: Arc<dyn LocalMultiply>,
        partitioner: Box<dyn Partitioner<PairKey>>,
    ) -> Self {
        Self {
            plan,
            mapper: Mapper2d { plan },
            reducer: Reducer2d { plan, backend },
            partitioner,
        }
    }

    /// The validated plan.
    pub fn plan(&self) -> Plan2d {
        self.plan
    }

    /// Build the static input pairs from the two matrices.
    pub fn static_input(
        plan: Plan2d,
        a: &DenseMatrix,
        b: &DenseMatrix,
    ) -> Vec<crate::mapreduce::Pair<PairKey, Strip>> {
        let s = plan.strips();
        let h = plan.strip_height();
        let side = plan.side;
        assert_eq!(a.rows(), side);
        assert_eq!(b.rows(), side);
        let mut out = Vec::with_capacity(2 * s);
        for i in 0..s {
            // Row strip of A: block (i, 0) of an (h × side)-block grid.
            out.push(crate::mapreduce::Pair::new(
                PairKey::a_input(i),
                Strip::a(a.block(i, 0, h, side)),
            ));
        }
        for j in 0..s {
            out.push(crate::mapreduce::Pair::new(
                PairKey::b_input(j),
                Strip::b(b.block(0, j, side, h)),
            ));
        }
        out
    }

    /// Assemble the output matrix from the C blocks of all rounds.
    pub fn assemble_output(
        plan: Plan2d,
        pairs: &[crate::mapreduce::Pair<PairKey, Strip>],
    ) -> DenseMatrix {
        let s = plan.strips();
        let mut out = DenseMatrix::zeros(plan.side, plan.side);
        let mut seen = vec![false; s * s];
        for p in pairs {
            let (i, j) = (p.key.i as usize, p.key.j as usize);
            assert!(!seen[i * s + j], "duplicate output block ({i},{j})");
            seen[i * s + j] = true;
            match &p.value {
                Strip::C(m) => out.set_block(i, j, m),
                _ => panic!("non-C in 2D output"),
            }
        }
        assert!(seen.iter().all(|&x| x), "missing output blocks");
        out
    }
}

impl MultiRoundAlgorithm for Algo2d {
    type K = PairKey;
    type V = Strip;

    fn num_rounds(&self) -> usize {
        self.plan.rounds()
    }

    fn mapper(&self, _round: usize) -> &dyn Mapper<PairKey, Strip> {
        &self.mapper
    }

    fn reducer(&self, _round: usize) -> &dyn Reducer<PairKey, Strip> {
        &self.reducer
    }

    fn partitioner(&self, _round: usize) -> &dyn Partitioner<PairKey> {
        self.partitioner.as_ref()
    }

    fn carries_output(&self) -> bool {
        false // every round's C blocks are final output
    }

    fn groups_hint(&self, _round: usize) -> Option<usize> {
        // Round r computes the ρ subproblems (i, (i+ℓ+rρ) mod s) for
        // each of the s row strips: sρ live (i,j) keys every round.
        Some(self.plan.strips() * self.plan.rho)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::m3::partitioner::BalancedPartitioner2d;
    use crate::mapreduce::{Driver, EngineConfig};
    use crate::matrix::gen;
    use crate::runtime::NaiveMultiply;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    fn cfg() -> EngineConfig {
        EngineConfig {
            map_tasks: 3,
            reduce_tasks: 3,
            workers: 3,
        }
    }

    fn run_2d(side: usize, m: usize, rho: usize, seed: u64) -> (DenseMatrix, DenseMatrix) {
        let plan = Plan2d::new(side, m, rho).unwrap();
        let mut rng = Xoshiro256ss::new(seed);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let alg = Algo2d::new(
            plan,
            Arc::new(NaiveMultiply),
            Box::new(BalancedPartitioner2d {
                strips: plan.strips(),
                rho,
            }),
        );
        let input = Algo2d::static_input(plan, &a, &b);
        let mut driver = Driver::new(cfg());
        let res = driver.run(&alg, &input);
        let got = Algo2d::assemble_output(plan, &res.output);
        (got, a.matmul_naive(&b))
    }

    #[test]
    fn multiplies_correctly_multiround() {
        let (got, want) = run_2d(16, 64, 1, 1); // s=4, R=4
        assert_eq!(got, want);
    }

    #[test]
    fn multiplies_correctly_monolithic() {
        let (got, want) = run_2d(16, 64, 4, 2); // s=4, R=1
        assert_eq!(got, want);
    }

    #[test]
    fn multiplies_correctly_intermediate() {
        let (got, want) = run_2d(16, 64, 2, 3); // R=2
        assert_eq!(got, want);
    }

    #[test]
    fn prop_2d_all_geometries() {
        run_prop("2d multiply correct", 8, |case| {
            // side must have s = n/m with ρ | s and m % side == 0.
            let side = 8 * (1 + case.size(0, 2)); // 8, 16, 24
            let strips_choices: Vec<usize> = (2..=side / 2)
                .filter(|&s| (side * side) % s == 0 && (side * side / s) % side == 0)
                .collect();
            let s = strips_choices[case.rng.next_usize(strips_choices.len())];
            let m = side * side / s;
            let divisors: Vec<usize> = (1..=s).filter(|d| s % d == 0).collect();
            let rho = divisors[case.rng.next_usize(divisors.len())];
            let (got, want) = run_2d(side, m, rho, case.rng.next_u64());
            if got != want {
                return Err(format!("mismatch side={side} m={m} rho={rho}"));
            }
            Ok(())
        });
    }

    #[test]
    fn shuffle_bound_theorem_3_3() {
        // Shuffle ≤ 2ρ·s strips per round ⇒ ≤ 2ρn words.
        let side = 16;
        let m = 64;
        let rho = 2;
        let plan = Plan2d::new(side, m, rho).unwrap();
        let mut rng = Xoshiro256ss::new(4);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let alg = Algo2d::new(
            plan,
            Arc::new(NaiveMultiply),
            Box::new(BalancedPartitioner2d {
                strips: plan.strips(),
                rho,
            }),
        );
        let input = Algo2d::static_input(plan, &a, &b);
        let mut driver = Driver::new(cfg());
        let res = driver.run(&alg, &input);
        for m in &res.metrics.rounds {
            assert!(m.shuffle_words <= plan.shuffle_words_bound());
            assert!(m.max_reducer_words <= plan.reducer_words_bound());
        }
    }

    #[test]
    fn strips_have_expected_shapes() {
        let plan = Plan2d::new(16, 64, 1).unwrap();
        let a = DenseMatrix::zeros(16, 16);
        let b = DenseMatrix::zeros(16, 16);
        let input = Algo2d::static_input(plan, &a, &b);
        assert_eq!(input.len(), 8); // 4 A strips + 4 B strips
        for p in &input {
            match &p.value {
                Strip::A(m) => assert_eq!((m.rows(), m.cols()), (4, 16)),
                Strip::B(m) => assert_eq!((m.rows(), m.cols()), (16, 4)),
                Strip::C(_) => panic!("no C in input"),
            }
        }
    }
}
