//! The 2D algorithm (paper Algorithm 2) — the baseline the 3D approach
//! is compared against in Figure 6.
//!
//! A is split into `s = n/m` row strips `A_i` of shape `m/√n × √n`, B
//! into `s` column strips `B_j` of shape `√n × m/√n`; output block
//! `C[i,j] = A_i · B_j` is computed by a single reducer. Round `r`
//! computes the subproblems `(i, j)` on the diagonals
//! `(j - i) mod s ∈ [offset(r), offset(r) + width(r))` of a
//! [`StripSchedule`] (the fixed-ρ plan is the uniform schedule, where
//! round `r` covers `[rρ, rρ + ρ)`); rounds are independent (no
//! accumulators carried), so every round's reduce output is final.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::mapreduce::driver::MultiRoundAlgorithm;
use crate::mapreduce::types::{Mapper, Partitioner, Reducer, Value};
use crate::mapreduce::wire::{ByteReader, CodecHandle, Wire, WireError, WirePairCodec};
use crate::matrix::DenseMatrix;
use crate::runtime::LocalMultiply;

use super::keys::{umod, PairKey};
use super::planner::Plan2d;

/// A 2D payload: an input strip or an output block. `Arc`-backed so
/// the ρ-way map fan-out and per-round static-input re-feed clone
/// pointers, not strip storage (same ownership rules as
/// [`crate::m3::multiply::DenseBlock`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Strip {
    /// Row strip `A_i`, shape `m/√n × √n`.
    A(Arc<DenseMatrix>),
    /// Column strip `B_j`, shape `√n × m/√n`.
    B(Arc<DenseMatrix>),
    /// Output block `C[i,j]`, shape `m/√n × m/√n`.
    C(Arc<DenseMatrix>),
}

impl Strip {
    /// Wrap a row strip of `A`.
    pub fn a(m: DenseMatrix) -> Self {
        Strip::A(Arc::new(m))
    }

    /// Wrap a column strip of `B`.
    pub fn b(m: DenseMatrix) -> Self {
        Strip::B(Arc::new(m))
    }

    /// Wrap an output block.
    pub fn c(m: DenseMatrix) -> Self {
        Strip::C(Arc::new(m))
    }
}

impl Value for Strip {
    fn words(&self) -> usize {
        match self {
            Strip::A(m) | Strip::B(m) | Strip::C(m) => m.words(),
        }
    }
}

/// Wire form: one variant byte (`0`/`1`/`2` = `A`/`B`/`C`), then the
/// strip matrix in its self-describing encoding — the same layout as
/// [`crate::m3::multiply::DenseBlock`], shapes included, so
/// single-element strips and non-square blocks round-trip exactly.
impl Wire for Strip {
    fn wire_encode(&self, out: &mut Vec<u8>) {
        let (tag, m) = match self {
            Strip::A(m) => (0u8, m),
            Strip::B(m) => (1u8, m),
            Strip::C(m) => (2u8, m),
        };
        out.push(tag);
        m.wire_encode(out);
    }

    fn wire_decode(r: &mut ByteReader) -> Result<Self, WireError> {
        let tag = r.u8()?;
        if tag > 2 {
            return Err(WireError::Corrupt("unknown strip variant"));
        }
        let m = Arc::new(DenseMatrix::wire_decode(r)?);
        Ok(match tag {
            0 => Strip::A(m),
            1 => Strip::B(m),
            _ => Strip::C(m),
        })
    }
}

/// Per-round diagonal-width schedule of a 2D run.
///
/// Round `r` computes the subproblems `(i, j)` on the `widths[r]`
/// diagonals `(j - i) mod s ∈ [offset(r), offset(r) + widths[r])`.
/// Unlike the 3D [`super::algo3d::RhoSchedule`], 2D rounds carry
/// nothing — every round reads the static strips and its reduce output
/// is final — so a mid-run re-plan may install *any* positive widths
/// covering the remaining diagonals: narrowing is as legal as widening
/// and there is no non-decreasing constraint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripSchedule {
    s: usize,
    widths: Vec<usize>,
    /// `offsets[r]` = first diagonal of round `r` (prefix sums of
    /// `widths`, precomputed: [`Self::offset`] sits on the per-key
    /// mapper/reducer hot path).
    offsets: Vec<usize>,
}

impl StripSchedule {
    /// Validate and construct a schedule over `s` diagonals.
    pub fn new(s: usize, widths: Vec<usize>) -> Result<Self> {
        if s == 0 || widths.is_empty() {
            bail!("schedule needs s ≥ 1 and at least one round");
        }
        if widths.iter().any(|&w| w == 0) {
            bail!("round widths must be positive: {widths:?}");
        }
        let total: usize = widths.iter().sum();
        if total != s {
            bail!("round widths sum to {total}, expected s = {s}");
        }
        let mut offsets = Vec::with_capacity(widths.len());
        let mut acc = 0usize;
        for &w in &widths {
            offsets.push(acc);
            acc += w;
        }
        Ok(Self { s, widths, offsets })
    }

    /// The uniform schedule of a fixed-ρ plan (`s/ρ` rounds of `ρ`).
    ///
    /// # Panics
    /// Panics unless `1 ≤ ρ ≤ s` and `ρ | s` (what [`Plan2d`] validates).
    pub fn uniform(s: usize, rho: usize) -> Self {
        assert!(
            (1..=s).contains(&rho) && s % rho == 0,
            "invalid uniform rho={rho} s={s}"
        );
        Self::new(s, vec![rho; s / rho]).expect("uniform schedules are valid by construction")
    }

    /// Strips per input matrix `s` (= diagonals to cover).
    pub fn s(&self) -> usize {
        self.s
    }

    /// Per-round diagonal widths.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Number of rounds.
    pub fn rounds(&self) -> usize {
        self.widths.len()
    }

    /// Width of round `r`.
    pub fn width(&self, r: usize) -> usize {
        self.widths[r]
    }

    /// First diagonal of round `r` (precomputed prefix sum).
    pub fn offset(&self, r: usize) -> usize {
        self.offsets[r]
    }

    /// Replace the widths from round `from_round` on with `tail`,
    /// keeping the committed prefix. Any positive tail covering the
    /// remaining diagonals is legal.
    pub fn with_tail(&self, from_round: usize, tail: Vec<usize>) -> Result<Self> {
        if from_round > self.widths.len() {
            bail!(
                "tail starts at round {from_round}, schedule has {}",
                self.widths.len()
            );
        }
        let mut widths = self.widths[..from_round].to_vec();
        widths.extend(tail);
        Self::new(self.s, widths)
    }
}

/// Map function of Algorithm 2.
pub struct Mapper2d {
    sched: StripSchedule,
}

impl Mapper<PairKey, Strip> for Mapper2d {
    fn map(&self, round: usize, key: &PairKey, value: &Strip, emit: &mut dyn FnMut(PairKey, Strip)) {
        let s = self.sched.s();
        let off = self.sched.offset(round);
        let w = self.sched.width(round);
        match value {
            Strip::A(_) => {
                let i = key.i as usize;
                for l in 0..w {
                    let j = (i + off + l) % s;
                    emit(PairKey::new(i, j), value.clone());
                }
            }
            Strip::B(_) => {
                let j = key.j as usize;
                for l in 0..w {
                    let i = umod(j as isize - (off + l) as isize, s);
                    emit(PairKey::new(i, j), value.clone());
                }
            }
            Strip::C(_) => {
                // C strips are final output; they are never re-mapped
                // (the driver does not carry them).
                unreachable!("C blocks must not re-enter the 2D pipeline");
            }
        }
    }
}

/// Reduce function of Algorithm 2: `C[i,j] = A_i · B_j`.
pub struct Reducer2d {
    sched: StripSchedule,
    backend: Arc<dyn LocalMultiply>,
}

impl Reducer<PairKey, Strip> for Reducer2d {
    fn reduce(
        &self,
        round: usize,
        key: &PairKey,
        values: Vec<Strip>,
        emit: &mut dyn FnMut(PairKey, Strip),
    ) {
        let s = self.sched.s();
        let off = self.sched.offset(round);
        let w = self.sched.width(round);
        // Liveness check: ℓ = (j - i - offset) mod s must be < width.
        let l = umod(key.j as isize - key.i as isize - off as isize, s);
        debug_assert!(l < w, "2D reducer key {key:?} not live in round {round}");
        let mut a = None;
        let mut b = None;
        for v in values {
            match v {
                Strip::A(m) => {
                    assert!(a.is_none(), "duplicate A strip at {key:?}");
                    a = Some(m);
                }
                Strip::B(m) => {
                    assert!(b.is_none(), "duplicate B strip at {key:?}");
                    b = Some(m);
                }
                Strip::C(_) => panic!("unexpected C at 2D reducer {key:?}"),
            }
        }
        let a = a.unwrap_or_else(|| panic!("missing A strip at {key:?}"));
        let b = b.unwrap_or_else(|| panic!("missing B strip at {key:?}"));
        // The 2D reducer never carries an accumulator, so the product
        // is written straight into one fresh zero buffer.
        let c = self
            .backend
            .multiply_acc_into(&a, &b, DenseMatrix::zeros(a.rows(), b.cols()));
        emit(*key, Strip::c(c));
    }
}

/// The full 2D algorithm.
pub struct Algo2d {
    plan: Plan2d,
    sched: StripSchedule,
    backend: Arc<dyn LocalMultiply>,
    mapper: Mapper2d,
    reducer: Reducer2d,
    partitioner: Box<dyn Partitioner<PairKey>>,
}

impl Algo2d {
    /// Assemble the 2D algorithm (uniform schedule from the plan's ρ).
    pub fn new(
        plan: Plan2d,
        backend: Arc<dyn LocalMultiply>,
        partitioner: Box<dyn Partitioner<PairKey>>,
    ) -> Self {
        let sched = StripSchedule::uniform(plan.strips(), plan.rho);
        Self {
            mapper: Mapper2d { sched: sched.clone() },
            reducer: Reducer2d { sched: sched.clone(), backend: backend.clone() },
            sched,
            plan,
            backend,
            partitioner,
        }
    }

    /// The validated plan.
    pub fn plan(&self) -> Plan2d {
        self.plan
    }

    /// The diagonal schedule in use.
    pub fn schedule(&self) -> &StripSchedule {
        &self.sched
    }

    /// Re-plan the rounds from `from_round` on with a new width
    /// sequence (the committed prefix is untouched, so a resumable run
    /// may call this at any round boundary ≤ its next pending round).
    /// Because 2D rounds carry nothing, the tail may be *any* positive
    /// cover of the remaining diagonals — the re-splits the 3D
    /// re-planner's non-decreasing rule forbids are legal here. The
    /// partitioner is kept as constructed (partitioning is
    /// correctness-neutral).
    pub fn set_tail_widths(&mut self, from_round: usize, tail: Vec<usize>) -> Result<()> {
        let sched = self.sched.with_tail(from_round, tail)?;
        self.mapper = Mapper2d { sched: sched.clone() };
        self.reducer = Reducer2d { sched: sched.clone(), backend: self.backend.clone() };
        self.sched = sched;
        Ok(())
    }

    /// Build the static input pairs from the two matrices.
    pub fn static_input(
        plan: Plan2d,
        a: &DenseMatrix,
        b: &DenseMatrix,
    ) -> Vec<crate::mapreduce::Pair<PairKey, Strip>> {
        let s = plan.strips();
        let h = plan.strip_height();
        let side = plan.side;
        assert_eq!(a.rows(), side);
        assert_eq!(b.rows(), side);
        let mut out = Vec::with_capacity(2 * s);
        for i in 0..s {
            // Row strip of A: block (i, 0) of an (h × side)-block grid.
            out.push(crate::mapreduce::Pair::new(
                PairKey::a_input(i),
                Strip::a(a.block(i, 0, h, side)),
            ));
        }
        for j in 0..s {
            out.push(crate::mapreduce::Pair::new(
                PairKey::b_input(j),
                Strip::b(b.block(0, j, side, h)),
            ));
        }
        out
    }

    /// Assemble the output matrix from the C blocks of all rounds.
    pub fn assemble_output(
        plan: Plan2d,
        pairs: &[crate::mapreduce::Pair<PairKey, Strip>],
    ) -> DenseMatrix {
        let s = plan.strips();
        let mut out = DenseMatrix::zeros(plan.side, plan.side);
        let mut seen = vec![false; s * s];
        for p in pairs {
            let (i, j) = (p.key.i as usize, p.key.j as usize);
            assert!(!seen[i * s + j], "duplicate output block ({i},{j})");
            seen[i * s + j] = true;
            match &p.value {
                Strip::C(m) => out.set_block(i, j, m),
                _ => panic!("non-C in 2D output"),
            }
        }
        assert!(seen.iter().all(|&x| x), "missing output blocks");
        out
    }
}

impl MultiRoundAlgorithm for Algo2d {
    type K = PairKey;
    type V = Strip;

    fn num_rounds(&self) -> usize {
        self.sched.rounds()
    }

    fn mapper(&self, _round: usize) -> &dyn Mapper<PairKey, Strip> {
        &self.mapper
    }

    fn reducer(&self, _round: usize) -> &dyn Reducer<PairKey, Strip> {
        &self.reducer
    }

    fn partitioner(&self, _round: usize) -> &dyn Partitioner<PairKey> {
        self.partitioner.as_ref()
    }

    fn carries_output(&self) -> bool {
        false // every round's C blocks are final output
    }

    fn codec(&self) -> Option<CodecHandle<PairKey, Strip>> {
        Some(Arc::new(WirePairCodec::default()))
    }

    fn groups_hint(&self, round: usize) -> Option<usize> {
        // Round r computes width(r) subproblems per row strip:
        // s·width(r) live (i,j) keys.
        Some(self.sched.s() * self.sched.width(round))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::m3::partitioner::BalancedPartitioner2d;
    use crate::mapreduce::{Driver, EngineConfig};
    use crate::matrix::gen;
    use crate::runtime::NaiveMultiply;
    use crate::util::prop::run_prop;
    use crate::util::rng::Xoshiro256ss;

    fn cfg() -> EngineConfig {
        EngineConfig {
            map_tasks: 3,
            reduce_tasks: 3,
            workers: 3,
        }
    }

    fn run_2d(side: usize, m: usize, rho: usize, seed: u64) -> (DenseMatrix, DenseMatrix) {
        let plan = Plan2d::new(side, m, rho).unwrap();
        let mut rng = Xoshiro256ss::new(seed);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let alg = Algo2d::new(
            plan,
            Arc::new(NaiveMultiply),
            Box::new(BalancedPartitioner2d {
                strips: plan.strips(),
                rho,
            }),
        );
        let input = Algo2d::static_input(plan, &a, &b);
        let mut driver = Driver::new(cfg());
        let res = driver.run(&alg, &input);
        let got = Algo2d::assemble_output(plan, &res.output);
        (got, a.matmul_naive(&b))
    }

    #[test]
    fn multiplies_correctly_multiround() {
        let (got, want) = run_2d(16, 64, 1, 1); // s=4, R=4
        assert_eq!(got, want);
    }

    #[test]
    fn multiplies_correctly_monolithic() {
        let (got, want) = run_2d(16, 64, 4, 2); // s=4, R=1
        assert_eq!(got, want);
    }

    #[test]
    fn multiplies_correctly_intermediate() {
        let (got, want) = run_2d(16, 64, 2, 3); // R=2
        assert_eq!(got, want);
    }

    #[test]
    fn prop_2d_all_geometries() {
        run_prop("2d multiply correct", 8, |case| {
            // side must have s = n/m with ρ | s and m % side == 0.
            let side = 8 * (1 + case.size(0, 2)); // 8, 16, 24
            let strips_choices: Vec<usize> = (2..=side / 2)
                .filter(|&s| (side * side) % s == 0 && (side * side / s) % side == 0)
                .collect();
            let s = strips_choices[case.rng.next_usize(strips_choices.len())];
            let m = side * side / s;
            let divisors: Vec<usize> = (1..=s).filter(|d| s % d == 0).collect();
            let rho = divisors[case.rng.next_usize(divisors.len())];
            let (got, want) = run_2d(side, m, rho, case.rng.next_u64());
            if got != want {
                return Err(format!("mismatch side={side} m={m} rho={rho}"));
            }
            Ok(())
        });
    }

    #[test]
    fn strip_schedule_allows_arbitrary_resplits() {
        assert!(StripSchedule::new(8, vec![4, 2, 2]).is_ok(), "narrowing is legal in 2D");
        assert!(StripSchedule::new(8, vec![2, 2]).is_err(), "incomplete");
        assert!(StripSchedule::new(8, vec![2, 2, 2, 2, 2]).is_err(), "overfull");
        assert!(StripSchedule::new(8, vec![0, 8]).is_err(), "zero width");
        assert!(StripSchedule::new(0, vec![1]).is_err(), "s = 0");
        let s = StripSchedule::new(8, vec![1, 3, 4]).unwrap();
        assert_eq!(s.rounds(), 3);
        assert_eq!(s.offset(2), 4);
        assert!(s.with_tail(1, vec![4, 3]).is_ok(), "any cover of the rest");
        assert!(s.with_tail(1, vec![2, 2]).is_err(), "tail must keep the sum");
        assert!(s.with_tail(4, vec![1]).is_err(), "past the last round");
    }

    #[test]
    fn mid_run_tail_replan_preserves_the_product() {
        // Commit two ρ=1 rounds of an s=8 run, then install the
        // arbitrary re-split [3, 1, 2] for the pending diagonals —
        // widening *and* narrowing in one tail, legal precisely because
        // 2D rounds carry nothing. The output must stay bit-identical.
        use crate::mapreduce::StepRun;
        let plan = Plan2d::new(16, 32, 1).unwrap();
        let mut rng = Xoshiro256ss::new(9);
        let a = gen::dense_int(16, 16, &mut rng);
        let b = gen::dense_int(16, 16, &mut rng);
        let alg = Algo2d::new(
            plan,
            Arc::new(NaiveMultiply),
            Box::new(BalancedPartitioner2d {
                strips: plan.strips(),
                rho: 1,
            }),
        );
        let input = Algo2d::static_input(plan, &a, &b);
        let mut run = StepRun::new(cfg(), alg, input);
        assert_eq!(run.num_rounds(), 8);
        run.step_commit();
        run.step_commit();
        run.alg_mut().set_tail_widths(2, vec![3, 1, 2]).unwrap();
        assert_eq!(run.num_rounds(), 5, "widths [1, 1, 3, 1, 2]");
        assert_eq!(run.next_round(), 2);
        while !run.is_done() {
            run.step_commit();
        }
        let got = Algo2d::assemble_output(plan, &run.into_result().output);
        assert_eq!(got, a.matmul_naive(&b));
    }

    #[test]
    fn shuffle_bound_theorem_3_3() {
        // Shuffle ≤ 2ρ·s strips per round ⇒ ≤ 2ρn words.
        let side = 16;
        let m = 64;
        let rho = 2;
        let plan = Plan2d::new(side, m, rho).unwrap();
        let mut rng = Xoshiro256ss::new(4);
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let alg = Algo2d::new(
            plan,
            Arc::new(NaiveMultiply),
            Box::new(BalancedPartitioner2d {
                strips: plan.strips(),
                rho,
            }),
        );
        let input = Algo2d::static_input(plan, &a, &b);
        let mut driver = Driver::new(cfg());
        let res = driver.run(&alg, &input);
        for m in &res.metrics.rounds {
            assert!(m.shuffle_words <= plan.shuffle_words_bound());
            assert!(m.max_reducer_words <= plan.reducer_words_bound());
        }
    }

    #[test]
    fn strip_wire_roundtrips_including_single_element() {
        let mut rng = Xoshiro256ss::new(30);
        for (r, c) in [(1usize, 1usize), (4, 16), (16, 4), (1, 9)] {
            let m = gen::dense_uniform(r, c, &mut rng);
            for strip in [Strip::a(m.clone()), Strip::b(m.clone()), Strip::c(m.clone())] {
                let mut buf = Vec::new();
                strip.wire_encode(&mut buf);
                let mut rd = ByteReader::new(&buf);
                let back = Strip::wire_decode(&mut rd).unwrap();
                assert!(rd.is_empty());
                assert_eq!(back, strip);
            }
        }
        let mut buf = Vec::new();
        Strip::a(DenseMatrix::zeros(1, 1)).wire_encode(&mut buf);
        buf[0] = 3;
        assert!(Strip::wire_decode(&mut ByteReader::new(&buf)).is_err());
    }

    #[test]
    fn serialized_transport_reproduces_the_2d_product_exactly() {
        use crate::mapreduce::TransportSel;
        let plan = Plan2d::new(16, 64, 2).unwrap();
        let mut rng = Xoshiro256ss::new(31);
        let a = gen::dense_uniform(16, 16, &mut rng);
        let b = gen::dense_uniform(16, 16, &mut rng);
        let mk = || {
            Algo2d::new(
                plan,
                Arc::new(NaiveMultiply),
                Box::new(BalancedPartitioner2d {
                    strips: plan.strips(),
                    rho: 2,
                }),
            )
        };
        let input = Algo2d::static_input(plan, &a, &b);
        let mut zc = Driver::new(cfg());
        zc.set_transport(TransportSel::ZeroCopy);
        let want = zc.run(&mk(), &input);
        let mut ser = Driver::new(cfg()); // serialized inproc default
        let got = ser.run(&mk(), &input);
        assert_eq!(
            Algo2d::assemble_output(plan, &got.output).as_slice(),
            Algo2d::assemble_output(plan, &want.output).as_slice(),
        );
        assert_eq!(want.metrics.total_shuffle_bytes(), 0);
        assert!(got.metrics.total_shuffle_bytes() > 0);
        assert_eq!(
            got.metrics.total_shuffle_words(),
            want.metrics.total_shuffle_words()
        );
    }

    #[test]
    fn strips_have_expected_shapes() {
        let plan = Plan2d::new(16, 64, 1).unwrap();
        let a = DenseMatrix::zeros(16, 16);
        let b = DenseMatrix::zeros(16, 16);
        let input = Algo2d::static_input(plan, &a, &b);
        assert_eq!(input.len(), 8); // 4 A strips + 4 B strips
        for p in &input {
            match &p.value {
                Strip::A(m) => assert_eq!((m.rows(), m.cols()), (4, 16)),
                Strip::B(m) => assert_eq!((m.rows(), m.cols()), (16, 4)),
                Strip::C(_) => panic!("no C in input"),
            }
        }
    }
}
