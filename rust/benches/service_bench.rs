//! Service + executor benchmarks (in-house driver, `harness = false`).
//!
//! Groups:
//!
//! 1. **executor hot path** — `Pool::run_indexed` with many tiny tasks,
//!    against a per-result `Mutex<Option<T>>` baseline (the
//!    implementation the §Perf pass replaced) to show the win of the
//!    lock-free disjoint-slot writes.
//! 2. **service** — workload generation, and the round-level scheduler
//!    end-to-end under each policy on a small seeded workload.
//!
//! Run: `cargo bench --bench service_bench [-- --quick]`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use m3::mapreduce::executor::Pool;
use m3::mapreduce::EngineConfig;
use m3::runtime::native::NativeMultiply;
use m3::service::{generate, run_service, Policy, ServiceConfig, WorkloadConfig};
use m3::util::bench::{black_box, print_header, Bencher};

/// The pre-optimisation `run_indexed`: one `Mutex<Option<T>>` per task.
/// Kept here as the benchmark baseline only.
fn mutex_run_indexed<T, F>(workers: usize, num_tasks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Send + Sync,
{
    if num_tasks == 0 {
        return vec![];
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..num_tasks).map(|_| Mutex::new(None)).collect();
    let nthreads = workers.max(1).min(num_tasks);
    std::thread::scope(|scope| {
        let mut handles = vec![];
        for _ in 0..nthreads {
            handles.push(scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= num_tasks {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("task not executed"))
        .collect()
}

fn bench_executor(b: &Bencher) {
    println!("\n--- executor: many small tasks ---");
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let pool = Pool::new(workers);
    for &n in &[10_000usize, 100_000] {
        let r = b.bench(&format!("pool_run_indexed_{n}_tiny_tasks"), || {
            pool.run_indexed(n, |i| i.wrapping_mul(i)).len()
        });
        println!("{}", r.summary());
        let r = b.bench(&format!("mutex_baseline_{n}_tiny_tasks"), || {
            mutex_run_indexed(workers, n, |i| i.wrapping_mul(i)).len()
        });
        println!("{}", r.summary());
    }
    // Non-trivial payload: moves through the slots instead of copies.
    let r = b.bench("pool_run_indexed_20k_string_tasks", || {
        pool.run_indexed(20_000, |i| format!("{i}")).len()
    });
    println!("{}", r.summary());
    let r = b.bench("mutex_baseline_20k_string_tasks", || {
        mutex_run_indexed(workers, 20_000, |i| format!("{i}")).len()
    });
    println!("{}", r.summary());
}

fn bench_service(b: &Bencher) {
    println!("\n--- service: round-level scheduler ---");
    let cfg = WorkloadConfig {
        jobs: 8,
        tenants: 3,
        seed: 11,
        mean_interarrival_secs: 20.0,
        ..Default::default()
    };
    let r = b.bench("workload_generate_256_specs", || {
        generate(&WorkloadConfig {
            jobs: 256,
            ..cfg.clone()
        })
        .len()
    });
    println!("{}", r.summary());

    let specs = generate(&cfg);
    let engine = EngineConfig {
        map_tasks: 4,
        reduce_tasks: 4,
        workers: 4,
    };
    for policy in [Policy::Fifo, Policy::Fair, Policy::Srpt] {
        let scfg = ServiceConfig::new(engine, policy);
        let r = b.bench(&format!("serve_8_jobs_{}", policy.name()), || {
            let out = run_service(&specs, &scfg, Arc::new(NativeMultiply::new())).unwrap();
            black_box(out.completed.len())
        });
        println!("{}", r.summary());
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("M3_BENCH_QUICK").is_ok();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    println!("M3 service/executor benchmarks (in-house driver)");
    print_header();
    bench_executor(&b);
    bench_service(&b);
    println!("\ndone.");
}
