//! Kernel-throughput benchmark (`cargo bench --bench kernel_bench`):
//! every reduce-side compute kernel raced against the reference it
//! replaced — register-tiled f32 GEMM vs the scalar `i-k-j` row loop
//! and the naive triple loop, tiled semiring GEMM vs
//! `matmul_naive_sr` (Arithmetic / MinPlus / BoolOrAnd), and the
//! epoch-marked Gustavson SpGEMM vs the old touched-scan accumulator —
//! at sides {64, 256, 512} and ER inputs with {8, 32} nnz/row.
//!
//! The same measurements back the `m3 bench-kernels` CLI, which can
//! write them to `BENCH_kernels.json` — see
//! `m3::harness::kernel_bench`.
//!
//! Flags: `--quick` (or `M3_BENCH_QUICK=1`) shrinks the sweep for CI.

use m3::harness::{run_kernel_bench, KernelBenchConfig};

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("M3_BENCH_QUICK").is_ok();
    let cfg = if quick {
        KernelBenchConfig {
            sides: vec![64, 128],
            sparse_side: 256,
            quick: true,
            ..KernelBenchConfig::default()
        }
    } else {
        KernelBenchConfig::default()
    };
    println!(
        "M3 kernel benchmark (in-house driver; criterion unavailable offline){}",
        if quick { " [quick]" } else { "" }
    );
    let rep = run_kernel_bench(&cfg);
    println!("{}", rep.text);
    println!(
        "headline: semiring GEMM {:.2}x vs naive (target: >=2x at side 256), \
         SpGEMM {:.2}x vs touched-scan (target: >=1x)",
        rep.semiring_speedup_headline, rep.spgemm_speedup_headline
    );
}
