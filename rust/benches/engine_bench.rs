//! Engine-scaling benchmark (`cargo bench --bench engine_bench`):
//! shuffle throughput (pairs/sec) and per-round wall time for dense
//! n = 512 at ρ ∈ {1, q}, old sequential shuffle vs the parallel
//! map-side-partitioned pipeline, across worker counts.
//!
//! The same measurements back the `m3 bench-engine` CLI, which can
//! write them to `BENCH_engine.json` — see
//! `m3::harness::engine_bench`.
//!
//! Flags: `--quick` (or `M3_BENCH_QUICK=1`) shrinks the sweep for CI.

use m3::harness::{run_engine_bench, EngineBenchConfig};

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("M3_BENCH_QUICK").is_ok();
    let cfg = if quick {
        EngineBenchConfig {
            n: 64,
            block: 16,
            workers: vec![1, 8],
            synthetic_pairs: 1 << 16,
            quick: true,
            ..EngineBenchConfig::default()
        }
    } else {
        EngineBenchConfig::default()
    };
    println!(
        "M3 engine benchmark (in-house driver; criterion unavailable offline){}",
        if quick { " [quick]" } else { "" }
    );
    let rep = run_engine_bench(&cfg);
    println!("{}", rep.text);
    println!(
        "headline speedup: {:.2}x (target: >=2x at 8 workers)",
        rep.headline_speedup
    );
}
