//! Benchmark harness (`cargo bench`) — criterion is unavailable in the
//! offline sandbox, so this uses the in-house `util::bench` driver.
//!
//! Two groups:
//!
//! 1. **paper figures** — one bench per figure, running the figure's
//!    sweep end-to-end (Figure 1 exactly; 2–10 through the simulator at
//!    paper scale, plus *real-engine* scaled-down counterparts of the
//!    core sweeps with the XLA backend when artifacts are present).
//! 2. **hot paths** — the kernels the §Perf pass optimises: local
//!    multiply (naive / native / XLA), shuffle group-by, partitioners,
//!    and block split/assemble.

use std::sync::Arc;

use m3::harness;
use m3::m3::partitioner::{BalancedPartitioner3d, NaiveTriplePartitioner};
use m3::m3::{multiply_dense_2d, multiply_dense_3d, M3Config, PartitionerKind, TripleKey};
use m3::mapreduce::shuffle::shuffle;
use m3::mapreduce::types::Partitioner;
use m3::mapreduce::{EngineConfig, Pair, TransportSel};
use m3::matrix::{gen, BlockGrid, DenseMatrix};
use m3::runtime::artifacts::default_dir;
use m3::runtime::native::NativeMultiply;
use m3::runtime::xla_backend::XlaMultiply;
use m3::runtime::{LocalMultiply, NaiveMultiply};
use m3::util::bench::{print_header, Bencher};
use m3::util::rng::Xoshiro256ss;

fn engine() -> EngineConfig {
    EngineConfig::cluster(
        8,
        2,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )
}

fn bench_figures(b: &Bencher) {
    println!("\n--- paper figures (simulated at paper scale) ---");
    for num in 1..=10usize {
        let r = b.bench(&format!("fig{num:02}_regenerate"), || {
            harness::figure(num).len()
        });
        println!("{}", r.summary());
    }
}

fn bench_real_engine(b: &Bencher) {
    println!("\n--- real-engine counterparts (side=1024, q=8) ---");
    let side = 1024;
    let block = 128;
    let mut rng = Xoshiro256ss::new(1);
    let a = gen::dense_int(side, side, &mut rng);
    let bm = gen::dense_int(side, side, &mut rng);

    // Figure 3 analogue: time vs replication on the real engine.
    for rho in [8usize, 4, 2, 1] {
        let cfg = M3Config {
            block_side: block,
            rho,
            engine: engine(),
            partitioner: PartitionerKind::Balanced,
            transport: TransportSel::default(),
        };
        let r = b.bench(&format!("fig03_real_dense3d_rho{rho}"), || {
            multiply_dense_3d(&a, &bm, &cfg, Arc::new(NativeMultiply::new())).unwrap()
        });
        println!("{}", r.summary());
    }
    // Figure 6 analogue: 2D vs 3D on the real engine.
    let cfg2 = M3Config {
        block_side: block,
        rho: 1,
        engine: engine(),
        partitioner: PartitionerKind::Balanced,
        transport: TransportSel::default(),
    };
    let r = b.bench("fig06_real_dense2d_rho1", || {
        multiply_dense_2d(&a, &bm, &cfg2, Arc::new(NativeMultiply::new())).unwrap()
    });
    println!("{}", r.summary());

    // XLA end-to-end when artifacts are present.
    if let Ok(x) = XlaMultiply::load_default(default_dir()) {
        let backend: Arc<dyn LocalMultiply> = Arc::new(x);
        let cfg = M3Config {
            block_side: 256,
            rho: 4,
            engine: engine(),
            partitioner: PartitionerKind::Balanced,
            transport: TransportSel::default(),
        };
        let r = b.bench("fig03_real_dense3d_rho4_xla_block256", || {
            multiply_dense_3d(&a, &bm, &cfg, backend.clone()).unwrap()
        });
        println!("{}", r.summary());
    } else {
        println!("(xla artifacts missing — run `make artifacts` for the XLA benches)");
    }
}

fn bench_local_multiply(b: &Bencher) {
    println!("\n--- hot path: local multiply C + A·B ---");
    let mut rng = Xoshiro256ss::new(2);
    let xla = XlaMultiply::load_default(default_dir()).ok().map(Arc::new);
    for side in [128usize, 256, 512] {
        let a = gen::dense_uniform(side, side, &mut rng);
        let bm = gen::dense_uniform(side, side, &mut rng);
        let c = gen::dense_uniform(side, side, &mut rng);
        let flops = 2.0 * (side as f64).powi(3);

        let native = NativeMultiply::new();
        let r = b.bench(&format!("gemm_native_{side}"), || {
            native.multiply_acc(&a, &bm, &c)
        });
        println!("{}  ({:.2} GFLOP/s)", r.summary(), flops / r.median() / 1e9);

        if let Some(x) = &xla {
            let r = b.bench(&format!("gemm_xla_{side}"), || x.multiply_acc(&a, &bm, &c));
            println!("{}  ({:.2} GFLOP/s)", r.summary(), flops / r.median() / 1e9);
        }
        if side <= 128 {
            let r = b.bench(&format!("gemm_naive_{side}"), || {
                NaiveMultiply.multiply_acc(&a, &bm, &c)
            });
            println!("{}  ({:.2} GFLOP/s)", r.summary(), flops / r.median() / 1e9);
        }
    }
}

fn bench_shuffle_and_partitioners(b: &Bencher) {
    println!("\n--- hot path: shuffle + partitioners ---");
    // 3ρq² pairs at q=32, rho=8: 24576 intermediate pairs.
    let (q, rho) = (32usize, 8usize);
    let mut pairs = vec![];
    for i in 0..q {
        for j in 0..q {
            for l in 0..rho {
                let h = (i + j + l) % q;
                pairs.push(Pair::new(TripleKey::new(i, h, j), 1.0f32));
            }
        }
    }
    let bal = BalancedPartitioner3d { q, rho };
    let r = b.bench("shuffle_24k_pairs_balanced", || {
        shuffle(pairs.clone(), &bal, 64).num_groups()
    });
    println!("{}", r.summary());
    let r = b.bench("shuffle_24k_pairs_naive", || {
        shuffle(pairs.clone(), &NaiveTriplePartitioner, 64).num_groups()
    });
    println!("{}", r.summary());

    let keys: Vec<TripleKey> = pairs.iter().map(|p| p.key).collect();
    let r = b.bench("partition_24k_keys_balanced", || {
        keys.iter().map(|k| bal.partition(k, 64)).sum::<usize>()
    });
    println!("{}", r.summary());
    let r = b.bench("partition_24k_keys_naive", || {
        keys.iter()
            .map(|k| NaiveTriplePartitioner.partition(k, 64))
            .sum::<usize>()
    });
    println!("{}", r.summary());
}

fn bench_block_ops(b: &Bencher) {
    println!("\n--- hot path: block split/assemble ---");
    let mut rng = Xoshiro256ss::new(3);
    let m = gen::dense_uniform(2048, 2048, &mut rng);
    let grid = BlockGrid::new(2048, 256);
    let r = b.bench("split_2048_into_256_blocks", || grid.split(&m).len());
    println!("{}", r.summary());
    let blocks = grid.split(&m);
    let r = b.bench("assemble_2048_from_256_blocks", || {
        grid.assemble(&blocks).rows()
    });
    println!("{}", r.summary());
    let zero = DenseMatrix::zeros(2048, 2048);
    let mut acc = zero.clone();
    let r = b.bench("block_sum_2048", || {
        acc.add_assign(&m);
    });
    println!("{}", r.summary());
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("M3_BENCH_QUICK").is_ok();
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    println!("M3 benchmark harness (in-house driver; criterion unavailable offline)");
    print_header();
    bench_figures(&b);
    bench_local_multiply(&b);
    bench_shuffle_and_partitioners(&b);
    bench_block_ops(&b);
    bench_real_engine(&b);
    println!("\ndone.");
}
