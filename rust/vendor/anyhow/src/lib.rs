//! Offline stand-in for the `anyhow` crate.
//!
//! The sandbox has no crates.io access, so this vendored shim provides
//! exactly the surface the `m3` crate uses — [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros — with the same call-site semantics. Swap the path dependency
//! for the real `anyhow = "1"` when a registry is available; no source
//! changes are needed.

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error`, it
/// deliberately does **not** implement `std::error::Error`, which is
/// what makes the blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors
    /// `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn helper(fail: bool) -> Result<u32> {
        ensure!(!fail, "failed with flag {fail}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(helper(false).unwrap(), 7);
        let e = helper(true).unwrap_err();
        assert_eq!(e.to_string(), "failed with flag true");
    }

    #[test]
    fn ensure_without_message() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 2);
            Ok(())
        }
        assert!(f(3).is_ok());
        assert!(f(1).unwrap_err().to_string().contains("x > 2"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: Option<u32> = None;
        assert_eq!(r.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("x={} y={}", 1, 2);
        assert_eq!(format!("{e}"), "x=1 y=2");
        assert_eq!(format!("{e:#}"), "x=1 y=2");
        assert_eq!(format!("{e:?}"), "x=1 y=2");
    }
}
