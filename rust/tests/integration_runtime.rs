//! Integration of the PJRT runtime with the full pipeline: AOT
//! artifacts → kernel servers → M3 reducers → exact products.
//!
//! These tests exercise the production hot path (XLA backend). They
//! skip gracefully when `make artifacts` has not run, so `cargo test`
//! stays green on a fresh checkout; CI runs them after the artifact
//! build.

use std::sync::Arc;

use m3::m3::{multiply_dense_3d, M3Config, PartitionerKind};
use m3::mapreduce::{EngineConfig, TransportSel};
use m3::matrix::gen;
use m3::runtime::artifacts::{default_dir, ArtifactSet};
use m3::runtime::xla_backend::XlaMultiply;
use m3::runtime::{LocalMultiply, NaiveMultiply};
use m3::util::rng::Xoshiro256ss;

fn xla() -> Option<Arc<XlaMultiply>> {
    let dir = default_dir();
    if ArtifactSet::discover(&dir).is_empty() {
        eprintln!("skipping: no artifacts in {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Arc::new(XlaMultiply::load(&dir, 2).expect("artifacts must compile")))
}

#[test]
fn artifact_set_covers_default_sides() {
    let dir = default_dir();
    let set = ArtifactSet::discover(&dir);
    if set.is_empty() {
        return;
    }
    for side in [64usize, 128, 256, 512] {
        assert!(
            set.matmul_acc(side).is_some(),
            "missing artifact for side {side}"
        );
    }
}

#[test]
fn xla_pipeline_exact_product_block128() {
    let Some(backend) = xla() else { return };
    let side = 512;
    let mut rng = Xoshiro256ss::new(20);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let cfg = M3Config {
        block_side: 128,
        rho: 2,
        engine: EngineConfig::default(),
        partitioner: PartitionerKind::Balanced,
        transport: TransportSel::default(),
    };
    let (got, _) = multiply_dense_3d(&a, &b, &cfg, backend.clone()).unwrap();
    assert_eq!(got, a.matmul_naive(&b));
    assert!(backend.xla_hits() > 0, "XLA path must actually be used");
    assert_eq!(backend.native_misses(), 0, "all blocks should hit XLA");
}

#[test]
fn xla_pipeline_all_artifact_sides() {
    let Some(backend) = xla() else { return };
    let mut rng = Xoshiro256ss::new(21);
    for &block in backend.sides().to_vec().iter().filter(|&&s| s <= 256) {
        let side = block * 2; // q = 2
        let a = gen::dense_int(side, side, &mut rng);
        let b = gen::dense_int(side, side, &mut rng);
        let cfg = M3Config {
            block_side: block,
            rho: 1,
            engine: EngineConfig::default(),
            partitioner: PartitionerKind::Balanced,
            transport: TransportSel::default(),
        };
        let (got, _) = multiply_dense_3d(&a, &b, &cfg, backend.clone()).unwrap();
        assert_eq!(got, a.matmul_naive(&b), "block={block}");
    }
}

#[test]
fn xla_kernel_matches_naive_on_float_data() {
    // Float (non-integer) data: XLA dot vs naive within f32 tolerance.
    let Some(backend) = xla() else { return };
    let side = 128;
    let mut rng = Xoshiro256ss::new(22);
    let a = gen::dense_uniform(side, side, &mut rng);
    let b = gen::dense_uniform(side, side, &mut rng);
    let c = gen::dense_uniform(side, side, &mut rng);
    let got = backend.multiply_acc(&a, &b, &c);
    let want = NaiveMultiply.multiply_acc(&a, &b, &c);
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-3, "max abs diff {diff}");
}

#[test]
fn xla_kernel_time_accumulates() {
    let Some(backend) = xla() else { return };
    let side = 64;
    let mut rng = Xoshiro256ss::new(23);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let c = gen::dense_int(side, side, &mut rng);
    let t0 = backend.kernel_time();
    let _ = backend.multiply_acc(&a, &b, &c);
    assert!(backend.kernel_time() > t0);
}

#[test]
fn hlo_text_artifacts_are_parseable() {
    // Each artifact must contain an HloModule with our f32 shapes —
    // guards against aot.py format drift.
    let dir = default_dir();
    let set = ArtifactSet::discover(&dir);
    if set.is_empty() {
        return;
    }
    for side in set.sides() {
        let text = std::fs::read_to_string(set.matmul_acc(side).unwrap()).unwrap();
        assert!(text.contains("HloModule"), "side {side}: no HloModule");
        assert!(
            text.contains(&format!("f32[{side},{side}]")),
            "side {side}: shape missing"
        );
    }
}
