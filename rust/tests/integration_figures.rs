//! Integration over the figure harness + simulator: every figure
//! regenerates, its CSV parses, and the paper's qualitative findings
//! hold in the emitted series (not just in the simulator's internals).

use m3::harness::{all_figures, figure};

/// Parse a CSV column as f64 (skipping the header and non-numeric
/// cells).
fn column(csv: &str, idx: usize) -> Vec<f64> {
    csv.lines()
        .skip(1)
        .filter_map(|l| l.split(',').nth(idx).and_then(|c| c.parse().ok()))
        .collect()
}

#[test]
fn every_figure_regenerates_with_csv() {
    for rep in all_figures() {
        assert!(!rep.text.is_empty(), "{}: empty text", rep.id);
        for (name, csv) in &rep.csv {
            assert!(csv.lines().count() >= 2, "{}/{name}: empty csv", rep.id);
            let header_cols = csv.lines().next().unwrap().split(',').count();
            for (i, line) in csv.lines().enumerate() {
                assert_eq!(
                    line.split(',').count(),
                    header_cols,
                    "{}/{name}: ragged row {i}",
                    rep.id
                );
            }
        }
    }
}

#[test]
fn fig1_balanced_perfectly_even_naive_not() {
    let rep = &figure(1)[0];
    let csv = &rep.csv[0].1; // per-task counts
    let naive = column(csv, 1);
    let balanced = column(csv, 2);
    assert_eq!(naive.len(), 64);
    let total_n: f64 = naive.iter().sum();
    let total_b: f64 = balanced.iter().sum();
    assert_eq!(total_n, 512.0, "all reducers assigned (naive)");
    assert_eq!(total_b, 512.0, "all reducers assigned (balanced)");
    assert!(balanced.iter().all(|&c| c == 8.0), "balanced: 8 per task");
    assert!(naive.iter().any(|&c| c != 8.0), "naive: uneven");
}

#[test]
fn fig2_time_decreases_with_m() {
    let rep = &figure(2)[0];
    let csv = &rep.csv[0].1;
    // Columns: sqrt_n, sqrt_m, max, min. For each sqrt_n the max-rho
    // times must decrease as sqrt_m grows (1000 → 2000 → 4000).
    for side in ["16000", "32000"] {
        let rows: Vec<Vec<&str>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').collect())
            .filter(|c: &Vec<&str>| c[0] == side && c[2] != "OOM")
            .collect();
        let times: Vec<f64> = rows.iter().map(|r| r[2].parse().unwrap()).collect();
        assert!(times.windows(2).all(|w| w[0] > w[1]), "side {side}: {times:?}");
    }
}

#[test]
fn fig3_monolithic_fastest_multiround_close() {
    for rep in figure(3) {
        let csv = &rep.csv[0].1;
        let rhos = column(csv, 0);
        let totals = column(csv, 2);
        // Totals decrease as rho increases (monolithic last).
        let mut pairs: Vec<(f64, f64)> = rhos.into_iter().zip(totals).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let ts: Vec<f64> = pairs.iter().map(|p| p.1).collect();
        assert!(
            ts.windows(2).all(|w| w[0] > w[1]),
            "{}: not monotone {ts:?}",
            rep.id
        );
        // Extreme multi-round within 2× of monolithic.
        assert!(ts[0] / ts[ts.len() - 1] < 2.0, "{}: gap too large", rep.id);
    }
}

#[test]
fn fig4_communication_dominates() {
    for rep in figure(4) {
        let csv = &rep.csv[0].1;
        let comm = column(csv, 1);
        let comp = column(csv, 2);
        for (c, p) in comm.iter().zip(&comp) {
            assert!(c > p, "{}: comm {c} !> comp {p}", rep.id);
        }
    }
}

#[test]
fn fig5_speedup_with_nodes_tapers() {
    let rep = &figure(5)[0];
    let csv = &rep.csv[0].1;
    // Columns: nodes, rho=1, rho=2, rho=4.
    for col in 1..=3 {
        let t = column(csv, col);
        assert_eq!(t.len(), 3);
        assert!(t[0] > t[1] && t[1] > t[2], "col {col}: {t:?}");
        let s1 = t[0] / t[1];
        let s2 = t[1] / t[2];
        assert!(s1 > s2, "col {col}: speedup should taper ({s1:.2} vs {s2:.2})");
    }
}

#[test]
fn fig7_sparse_times_grow_with_virtual_side() {
    let rep = &figure(7)[0];
    let csv = &rep.csv[0].1;
    // For rho=1 rows, time must grow with log2(side) 20 → 22 → 24.
    let rows: Vec<Vec<&str>> = csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
    let t_at = |lg: &str| -> f64 {
        rows.iter()
            .find(|r| r[0] == lg && r[2] == "1")
            .map(|r| r[4].parse().unwrap())
            .unwrap()
    };
    assert!(t_at("20") < t_at("22"));
    assert!(t_at("22") < t_at("24"));
}

#[test]
fn fig8_fig10_emr_slower_than_inhouse() {
    let in3 = &figure(3); // 3a = 16000 in-house
    let emr = &figure(8)[0]; // 16000 c3
    let t_in = column(&in3[0].csv[0].1, 2);
    let t_emr = column(&emr.csv[0].1, 2);
    for (i, e) in t_in.iter().zip(&t_emr) {
        assert!(e > i, "EMR {e} !> in-house {i}");
    }
}

#[test]
fn fig9_i2_comm_below_c3() {
    let figs = figure(9);
    let c3 = column(&figs[0].csv[0].1, 1);
    let i2 = column(&figs[1].csv[0].1, 1);
    for (c, i) in c3.iter().zip(&i2) {
        assert!(i < c, "i2 comm {i} !< c3 comm {c}");
    }
}

#[test]
fn fig10_per_round_breakdown_sums_to_total() {
    let figs = figure(10);
    let csv = &figs[0].csv[0].1; // fig10a time_vs_rho
    for line in csv.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let total: f64 = cells[2].parse().unwrap();
        let per_round: f64 = cells[3].split('+').map(|x| x.parse::<f64>().unwrap()).sum();
        assert!(
            (total - per_round).abs() <= 1.0 + 0.01 * total,
            "total {total} vs per-round sum {per_round}"
        );
    }
}
