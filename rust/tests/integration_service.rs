//! Integration: the multi-tenant round-level job service end-to-end —
//! correctness of every job's product under interleaving, the
//! round-level interleaving itself, policy behaviour on skewed
//! workloads, spot-market preemptions, and determinism.

use std::sync::Arc;

use m3::mapreduce::EngineConfig;
use m3::runtime::native::NativeMultiply;
use m3::runtime::NaiveMultiply;
use m3::service::{
    generate, run_service, skewed, JobKind, JobSpec, PlanChoice, Policy, ServiceConfig,
    WorkloadConfig,
};

fn engine() -> EngineConfig {
    EngineConfig {
        map_tasks: 4,
        reduce_tasks: 4,
        workers: 4,
    }
}

fn cfg(policy: Policy) -> ServiceConfig {
    ServiceConfig::new(engine(), policy)
}

/// The acceptance workload: `m3 serve --policy fair --jobs 16 --seed 7`.
#[test]
fn serve_fair_16_jobs_seed_7_all_products_exact() {
    let specs = generate(&WorkloadConfig {
        jobs: 16,
        tenants: 4,
        seed: 7,
        mean_interarrival_secs: 25.0,
        ..Default::default()
    });
    let out = run_service(&specs, &cfg(Policy::Fair), Arc::new(NativeMultiply::new())).unwrap();
    assert_eq!(out.completed.len(), 16, "every job must run to completion");
    for c in &out.completed {
        assert!(
            c.output.matches(&c.spec),
            "job {} ({:?}) produced a wrong product",
            c.spec.id,
            c.spec.kind
        );
        assert!(c.metrics.num_rounds() >= 1);
    }
    // Reports are complete and causally ordered.
    for r in &out.metrics.jobs {
        assert!(r.first_service_secs >= r.arrival_secs);
        assert!(r.completion_secs > r.first_service_secs);
        assert!(r.service_secs > 0.0);
    }
}

/// Acceptance: with ≥ 2 concurrent jobs, rounds of different jobs
/// alternate on the shared pool under fair share.
#[test]
fn concurrent_jobs_interleave_at_round_granularity() {
    let mk = |id: usize, tenant: usize| JobSpec {
        id,
        tenant,
        kind: JobKind::Dense3d {
            side: 16,
            block_side: 4,
            rho: 1, // 5 rounds: plenty of interleaving points
        },
        plan: PlanChoice::Fixed,
        seed: 50 + id as u64,
        arrival_secs: 0.0,
    };
    let specs = vec![mk(0, 0), mk(1, 1), mk(2, 2)];
    let out = run_service(&specs, &cfg(Policy::Fair), Arc::new(NaiveMultiply)).unwrap();
    let jobs: Vec<usize> = out.trace.iter().map(|t| t.job).collect();
    assert_eq!(jobs.len(), 15, "3 jobs x 5 rounds");
    // Before ANY job finishes its second round, every job has run its
    // first — that is round-level alternation, impossible for a
    // job-at-a-time executor.
    let first_three: std::collections::BTreeSet<usize> = jobs[..3].iter().copied().collect();
    assert_eq!(first_three.len(), 3, "each job's round 0 runs first: {jobs:?}");
    let switches = jobs.windows(2).filter(|w| w[0] != w[1]).count();
    assert!(switches >= 10, "rounds must alternate: {jobs:?}");
    // Interleaving must not corrupt any product.
    for c in &out.completed {
        assert!(c.output.matches(&c.spec), "job {} wrong", c.spec.id);
    }
}

/// Acceptance: fair share yields strictly lower mean queue wait than
/// FIFO on a skewed workload (one long job ahead of short ones).
#[test]
fn fair_share_beats_fifo_queue_wait_on_skewed_workload() {
    let specs = skewed(6, 42);
    let fifo = run_service(&specs, &cfg(Policy::Fifo), Arc::new(NativeMultiply::new())).unwrap();
    let fair = run_service(&specs, &cfg(Policy::Fair), Arc::new(NativeMultiply::new())).unwrap();
    let w_fifo = fifo.metrics.mean_queue_wait_secs();
    let w_fair = fair.metrics.mean_queue_wait_secs();
    assert!(
        w_fair < w_fifo,
        "fair mean wait {w_fair:.1}s must be strictly below fifo {w_fifo:.1}s"
    );
    // The gap is structural, not marginal: the short jobs sit behind
    // ~16 long rounds under FIFO.
    assert!(
        w_fair * 2.0 < w_fifo,
        "expected a large gap: fair {w_fair:.1}s vs fifo {w_fifo:.1}s"
    );
    // Both policies still compute every product exactly.
    for out in [&fifo, &fair] {
        for c in &out.completed {
            assert!(c.output.matches(&c.spec));
        }
    }
}

#[test]
fn srpt_minimises_mean_sojourn_on_mixed_sizes() {
    let specs = skewed(4, 9);
    let fifo = run_service(&specs, &cfg(Policy::Fifo), Arc::new(NativeMultiply::new())).unwrap();
    let srpt = run_service(&specs, &cfg(Policy::Srpt), Arc::new(NativeMultiply::new())).unwrap();
    assert!(
        srpt.metrics.mean_sojourn_secs() < fifo.metrics.mean_sojourn_secs(),
        "srpt {:.1}s !< fifo {:.1}s",
        srpt.metrics.mean_sojourn_secs(),
        fifo.metrics.mean_sojourn_secs()
    );
}

#[test]
fn spot_preemptions_discard_only_inflight_rounds_and_outputs_stay_exact() {
    let specs = skewed(3, 5);
    let mut c = cfg(Policy::Fair);
    // Several strikes across the workload's span.
    c.preemptions = vec![30.0, 90.0, 150.0];
    let out = run_service(&specs, &c, Arc::new(NativeMultiply::new())).unwrap();
    let m = &out.metrics;
    assert!(m.total_preemptions() >= 1, "at least one strike must land");
    assert!(m.total_discarded_secs() > 0.0);
    // Every committed round count still matches the logical plan:
    // executed = total + number of discarded attempts.
    for r in &m.jobs {
        assert_eq!(r.rounds_executed, r.rounds_total + r.preemptions);
    }
    let discarded = out.trace.iter().filter(|t| !t.committed).count();
    assert_eq!(discarded, m.total_preemptions());
    for c in &out.completed {
        assert!(
            c.output.matches(&c.spec),
            "job {} corrupted by preemption",
            c.spec.id
        );
    }
}

#[test]
fn schedule_is_deterministic_per_seed_policy_and_preemptions() {
    let specs = generate(&WorkloadConfig {
        jobs: 8,
        tenants: 3,
        seed: 21,
        mean_interarrival_secs: 15.0,
        ..Default::default()
    });
    for policy in [Policy::Fifo, Policy::Fair, Policy::Srpt] {
        let mut c = cfg(policy);
        c.preemptions = vec![50.0];
        let a = run_service(&specs, &c, Arc::new(NaiveMultiply)).unwrap();
        let b = run_service(&specs, &c, Arc::new(NaiveMultiply)).unwrap();
        assert_eq!(a.trace, b.trace, "{policy:?} schedule must be reproducible");
        assert_eq!(
            a.metrics.mean_queue_wait_secs(),
            b.metrics.mean_queue_wait_secs()
        );
    }
}

/// Acceptance: `m3 serve --auto-fraction 0.5` — mixed fixed/auto
/// tenants run end-to-end with exact products, with and without online
/// profile recalibration.
#[test]
fn mixed_auto_fixed_workload_serves_exactly() {
    let specs = generate(&WorkloadConfig {
        jobs: 12,
        tenants: 4,
        seed: 19,
        mean_interarrival_secs: 20.0,
        auto_fraction: 0.5,
        ..Default::default()
    });
    assert!(
        specs.iter().any(|s| s.plan != PlanChoice::Fixed)
            && specs.iter().any(|s| s.plan == PlanChoice::Fixed),
        "workload must actually mix plan choices"
    );
    for recalibrate in [false, true] {
        let mut c = cfg(Policy::Fair);
        c.recalibrate = recalibrate;
        let out = run_service(&specs, &c, Arc::new(NativeMultiply::new())).unwrap();
        assert_eq!(out.completed.len(), 12);
        for cj in &out.completed {
            assert!(
                cj.output.matches(&cj.spec),
                "job {} (recalibrate={recalibrate}) wrong product",
                cj.spec.id
            );
        }
    }
}

#[test]
fn tenant_accounting_covers_all_jobs() {
    let specs = generate(&WorkloadConfig {
        jobs: 10,
        tenants: 3,
        seed: 33,
        mean_interarrival_secs: 10.0,
        ..Default::default()
    });
    let out = run_service(&specs, &cfg(Policy::Fair), Arc::new(NativeMultiply::new())).unwrap();
    let tenants = out.metrics.by_tenant();
    let total: usize = tenants.iter().map(|t| t.jobs).sum();
    assert_eq!(total, 10);
    for t in &tenants {
        assert!(t.service_secs > 0.0);
    }
}
