//! Integration: the auto-planner against the planner formulas, the
//! simulator's volumes, and the real engine.
//!
//! * Property tests pin the planner's closed forms
//!   (`shuffle_words_bound`, `total_shuffle_words`, `reducer_flops`)
//!   to the summed [`RoundVolumes`] the simulator derives for the same
//!   plan, across a grid of valid `(side, block_side, ρ)` for all
//!   three algorithms — one model, two views.
//! * An equivalence test runs the same seed once with
//!   [`PlanChoice::Auto`] and once with the chosen plan passed
//!   explicitly: the products must be bit-identical and the round
//!   structure the same.

use std::sync::Arc;

use m3::m3::{plan_dense2d, plan_dense3d, plan_sparse3d, Plan2d, Plan3d, SparsePlan};
use m3::mapreduce::EngineConfig;
use m3::matrix::gen;
use m3::runtime::NaiveMultiply;
use m3::service::{spawn_job, ActiveJob, JobKind, JobOutput, JobSpec, PlanChoice};
use m3::simulator::{
    volumes_dense2d, volumes_dense3d, volumes_sparse3d, ClusterProfile, RoundVolumes,
};

fn divisors(x: usize) -> Vec<usize> {
    (1..=x).filter(|d| x % d == 0).collect()
}

fn sum_shuffle(vols: &[RoundVolumes]) -> f64 {
    vols.iter().map(|v| v.shuffle_words).sum()
}

#[test]
fn dense3d_formulas_agree_with_simulator_volumes() {
    for side in [16usize, 48, 64, 1024] {
        for block in divisors(side) {
            let q = side / block;
            if q > 32 {
                continue; // keep the grid small; shapes stay diverse
            }
            for rho in divisors(q) {
                let plan = Plan3d::new(side, block, rho).unwrap();
                let vols = volumes_dense3d(&plan);
                assert_eq!(vols.len(), plan.rounds(), "side={side} b={block} rho={rho}");
                // Per-round shuffle obeys the Theorem 3.1 bound 3ρn.
                for (r, v) in vols.iter().enumerate() {
                    assert!(
                        v.shuffle_words <= plan.shuffle_words_bound() as f64,
                        "side={side} b={block} rho={rho} round {r}"
                    );
                }
                // Summed shuffle equals the closed form 3nq exactly.
                assert_eq!(
                    sum_shuffle(&vols),
                    plan.total_shuffle_words() as f64,
                    "side={side} b={block} rho={rho}"
                );
                // Summed product-round flops equal reducer_flops ×
                // (number of block products) = 2m^{3/2} · q³ = 2n^{3/2}.
                let product_flops: f64 = vols[..vols.len() - 1].iter().map(|v| v.flops).sum();
                assert_eq!(
                    product_flops,
                    (plan.reducer_flops() * q * q * q) as f64,
                    "side={side} b={block} rho={rho}"
                );
            }
        }
    }
}

#[test]
fn dense2d_formulas_agree_with_simulator_volumes() {
    for side in [16usize, 32, 64] {
        for h in divisors(side) {
            let m = side * h;
            let s = side * side / m;
            for rho in divisors(s) {
                let plan = Plan2d::new(side, m, rho).unwrap();
                let vols = volumes_dense2d(&plan);
                assert_eq!(vols.len(), plan.rounds());
                for v in &vols {
                    assert_eq!(v.shuffle_words, plan.shuffle_words_bound() as f64);
                }
                assert_eq!(sum_shuffle(&vols), plan.total_shuffle_words() as f64);
            }
        }
    }
}

#[test]
fn sparse_formulas_bound_simulator_volumes() {
    for side in [64usize, 256, 1024] {
        for nnz in [2usize, 4, 8] {
            let delta = nnz as f64 / side as f64;
            let delta_m = delta.max(gen::er_output_density(side, delta));
            for block in divisors(side) {
                let q = side / block;
                if q > 16 {
                    continue;
                }
                for rho in divisors(q) {
                    let plan = SparsePlan::new(side, block, rho, delta, delta_m).unwrap();
                    let vols = volumes_sparse3d(&plan);
                    assert_eq!(vols.len(), plan.rounds());
                    // Every round's expected shuffle stays within the
                    // Theorem 3.2 bound 3ρ·δ_M·n.
                    for (r, v) in vols.iter().enumerate() {
                        assert!(
                            v.shuffle_words <= plan.expected_shuffle_words() * (1.0 + 1e-12),
                            "side={side} b={block} rho={rho} round {r}: {} > {}",
                            v.shuffle_words,
                            plan.expected_shuffle_words()
                        );
                    }
                }
            }
        }
    }
}

fn run_to_output(spec: &JobSpec) -> (JobOutput, usize) {
    let engine = EngineConfig {
        map_tasks: 4,
        reduce_tasks: 4,
        workers: 4,
    };
    let mut job = spawn_job(spec, engine, Arc::new(NaiveMultiply)).unwrap();
    let rounds = job.num_rounds();
    while !job.is_done() {
        job.step_commit();
    }
    (job.finish().0, rounds)
}

/// Acceptance: an auto-planned job's output is bit-identical to the
/// same job run with the chosen plan passed explicitly.
#[test]
fn auto_plan_output_identical_to_explicit_plan() {
    let profile = ClusterProfile::inhouse();
    let budget = 48;

    // Dense 3D: resolve the search the spawn path will run, then
    // submit both variants of the same seed.
    let (plan, _) = plan_dense3d(16, budget, &profile).unwrap();
    let auto = JobSpec {
        id: 0,
        tenant: 0,
        kind: JobKind::Dense3d {
            side: 16,
            block_side: 1,
            rho: 1,
        },
        plan: PlanChoice::Auto {
            memory_budget: budget,
        },
        seed: 77,
        arrival_secs: 0.0,
    };
    let explicit = JobSpec {
        kind: JobKind::Dense3d {
            side: 16,
            block_side: plan.block_side,
            rho: plan.rho,
        },
        plan: PlanChoice::Fixed,
        ..auto.clone()
    };
    let (out_a, rounds_a) = run_to_output(&auto);
    let (out_e, rounds_e) = run_to_output(&explicit);
    assert_eq!(rounds_a, rounds_e, "auto must run the chosen plan's rounds");
    match (&out_a, &out_e) {
        (JobOutput::Dense(a), JobOutput::Dense(e)) => {
            assert_eq!(a.max_abs_diff(e), 0.0, "products must be bit-identical")
        }
        _ => panic!("dense jobs must yield dense outputs"),
    }

    // Sparse: same contract.
    let (splan, _) = plan_sparse3d(64, 6, 768, &profile).unwrap();
    let auto = JobSpec {
        kind: JobKind::Sparse3d {
            side: 64,
            block_side: 1,
            rho: 1,
            nnz_per_row: 6,
        },
        plan: PlanChoice::Auto { memory_budget: 768 },
        ..auto.clone()
    };
    let explicit = JobSpec {
        kind: JobKind::Sparse3d {
            side: 64,
            block_side: splan.block_side,
            rho: splan.rho,
            nnz_per_row: 6,
        },
        plan: PlanChoice::Fixed,
        ..auto.clone()
    };
    let (out_a, rounds_a) = run_to_output(&auto);
    let (out_e, rounds_e) = run_to_output(&explicit);
    assert_eq!(rounds_a, rounds_e);
    match (&out_a, &out_e) {
        (JobOutput::Sparse(a), JobOutput::Sparse(e)) => {
            assert_eq!(
                a.to_dense().max_abs_diff(&e.to_dense()),
                0.0,
                "sparse products must be identical"
            )
        }
        _ => panic!("sparse jobs must yield sparse outputs"),
    }
}

/// The 2D auto path also spawns and matches its reference product.
#[test]
fn auto_plan_dense2d_runs_and_matches_reference() {
    let profile = ClusterProfile::inhouse();
    let (plan, search) = plan_dense2d(16, 768, &profile).unwrap();
    assert!(search.chosen().feasible);
    let auto = JobSpec {
        id: 0,
        tenant: 0,
        kind: JobKind::Dense2d {
            side: 16,
            block_side: 1,
            rho: 1,
        },
        plan: PlanChoice::Auto { memory_budget: 768 },
        seed: 5,
        arrival_secs: 0.0,
    };
    let (out, rounds) = run_to_output(&auto);
    assert_eq!(rounds, plan.rounds());
    assert!(out.matches(&auto), "auto 2D product must be exact");
}
