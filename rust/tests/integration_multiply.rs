//! Cross-module integration: the full M3 pipeline (engine + algorithms
//! + partitioners + backends) against reference products, across
//! geometries, payloads, and failure modes.

use std::sync::Arc;

use m3::m3::algo3d::{Algo3d, Geometry};
use m3::m3::multiply::{DenseBlock, DenseOps};
use m3::m3::partitioner::BalancedPartitioner3d;
use m3::m3::{
    multiply_dense_2d, multiply_dense_3d, multiply_sparse_3d, M3Config, PartitionerKind, Plan3d,
    SparsePlan, TripleKey,
};
use m3::mapreduce::{Driver, EngineConfig, Pair, TransportSel};
use m3::matrix::{gen, BlockGrid, DenseMatrix};
use m3::runtime::native::NativeMultiply;
use m3::runtime::NaiveMultiply;
use m3::util::rng::Xoshiro256ss;

fn engine() -> EngineConfig {
    EngineConfig {
        map_tasks: 8,
        reduce_tasks: 8,
        workers: 4,
    }
}

fn cfg(block: usize, rho: usize, part: PartitionerKind) -> M3Config {
    M3Config {
        block_side: block,
        rho,
        engine: engine(),
        partitioner: part,
        transport: TransportSel::default(),
    }
}

#[test]
fn dense_3d_full_sweep_exact() {
    let side = 64;
    let mut rng = Xoshiro256ss::new(10);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let want = a.matmul_naive(&b);
    for block in [8usize, 16, 32] {
        let q = side / block;
        for rho in (1..=q).filter(|r| q % r == 0) {
            for part in [PartitionerKind::Balanced, PartitionerKind::Naive] {
                let (got, metrics) =
                    multiply_dense_3d(&a, &b, &cfg(block, rho, part), Arc::new(NativeMultiply::new()))
                        .unwrap();
                assert_eq!(got, want, "block={block} rho={rho} part={part:?}");
                assert_eq!(metrics.num_rounds(), q / rho + 1);
            }
        }
    }
}

#[test]
fn dense_2d_full_sweep_exact() {
    let side = 32;
    let mut rng = Xoshiro256ss::new(11);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let want = a.matmul_naive(&b);
    // m = block², strips s = n/m.
    for block in [8usize, 16] {
        let s = side * side / (block * block);
        for rho in (1..=s).filter(|r| s % r == 0) {
            let (got, metrics) = multiply_dense_2d(
                &a,
                &b,
                &cfg(block, rho, PartitionerKind::Balanced),
                Arc::new(NativeMultiply::new()),
            )
            .unwrap();
            assert_eq!(got, want, "block={block} rho={rho}");
            assert_eq!(metrics.num_rounds(), s / rho);
        }
    }
}

#[test]
fn sparse_3d_matches_dense_pipeline() {
    let side = 128;
    let mut rng = Xoshiro256ss::new(12);
    let a = gen::erdos_renyi_coo(side, 0.05, &mut rng);
    let b = gen::erdos_renyi_coo(side, 0.05, &mut rng);
    let want = a.to_dense().matmul_naive(&b.to_dense());
    for (block, rho) in [(16usize, 1usize), (16, 2), (32, 4), (64, 2)] {
        let plan = SparsePlan::new(side, block, rho, 0.05, 0.3).unwrap();
        let (got, _) = multiply_sparse_3d(
            &a,
            &b,
            &plan,
            engine(),
            PartitionerKind::Balanced,
            TransportSel::default(),
        )
        .unwrap();
        assert_eq!(
            got.to_dense().max_abs_diff(&want),
            0.0,
            "block={block} rho={rho}"
        );
    }
}

#[test]
fn dense_3d_and_2d_agree() {
    let side = 32;
    let mut rng = Xoshiro256ss::new(13);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let (c3, _) = multiply_dense_3d(
        &a,
        &b,
        &cfg(8, 2, PartitionerKind::Balanced),
        Arc::new(NaiveMultiply),
    )
    .unwrap();
    let (c2, _) = multiply_dense_2d(
        &a,
        &b,
        &cfg(8, 2, PartitionerKind::Balanced),
        Arc::new(NaiveMultiply),
    )
    .unwrap();
    assert_eq!(c3, c2);
}

#[test]
fn theorem_bounds_hold_across_sweep() {
    // Shuffle ≤ 3ρn words and reducer ≤ 3m words in every round, for
    // every geometry (Theorem 3.1).
    let side = 48;
    let mut rng = Xoshiro256ss::new(14);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    for block in [8usize, 12, 16, 24] {
        let q = side / block;
        for rho in (1..=q).filter(|r| q % r == 0) {
            let plan = Plan3d::new(side, block, rho).unwrap();
            let (_, metrics) = multiply_dense_3d(
                &a,
                &b,
                &cfg(block, rho, PartitionerKind::Balanced),
                Arc::new(NativeMultiply::new()),
            )
            .unwrap();
            let last = metrics.num_rounds() - 1;
            for r in &metrics.rounds {
                assert!(
                    r.shuffle_words <= plan.shuffle_words_bound(),
                    "shuffle bound violated at block={block} rho={rho} round={}",
                    r.round
                );
                if r.round < last {
                    // Product rounds: A + B + C = 3m words (Thm 3.1).
                    assert!(
                        r.max_reducer_words <= plan.reducer_words_bound(),
                        "reducer bound violated at block={block} rho={rho} round={}",
                        r.round
                    );
                } else {
                    // Final round: ρ accumulators arrive (ρm input
                    // words); the 3m bound is on *memory*, which a
                    // streaming sum satisfies — check input = ρm.
                    assert!(
                        r.max_reducer_words <= rho * plan.m(),
                        "final-round input exceeds rho*m at block={block} rho={rho}"
                    );
                }
            }
        }
    }
}

#[test]
fn shuffle_pairs_scale_with_rho_rounds_inverse() {
    // The paper's core tradeoff: per-round shuffle ∝ ρ, rounds ∝ 1/ρ.
    let side = 64;
    let block = 8; // q = 8
    let mut rng = Xoshiro256ss::new(15);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let mut prev_shuffle = 0;
    let mut prev_rounds = usize::MAX;
    for rho in [1usize, 2, 4, 8] {
        let (_, metrics) = multiply_dense_3d(
            &a,
            &b,
            &cfg(block, rho, PartitionerKind::Balanced),
            Arc::new(NativeMultiply::new()),
        )
        .unwrap();
        assert!(metrics.max_shuffle_pairs() > prev_shuffle);
        assert!(metrics.num_rounds() < prev_rounds);
        prev_shuffle = metrics.max_shuffle_pairs();
        prev_rounds = metrics.num_rounds();
    }
}

#[test]
fn preempted_pipeline_still_exact() {
    let side = 64;
    let block = 16; // q = 4
    let rho = 2;
    let mut rng = Xoshiro256ss::new(16);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let want = a.matmul_naive(&b);
    let grid = BlockGrid::new(side, block);
    let geo: Geometry = Plan3d::new(side, block, rho).unwrap().into();
    let alg = Algo3d::new(
        geo,
        Arc::new(DenseOps::new(Arc::new(NativeMultiply::new()))),
        Box::new(BalancedPartitioner3d { q: geo.q, rho }),
    );
    let mut input: Vec<Pair<TripleKey, DenseBlock>> = vec![];
    for ((i, j), blk) in grid.split(&a) {
        input.push(Pair::new(TripleKey::io(i, j), DenseBlock::a(blk)));
    }
    for ((i, j), blk) in grid.split(&b) {
        input.push(Pair::new(TripleKey::io(i, j), DenseBlock::b(blk)));
    }
    let mut driver = Driver::new(engine());
    let res = driver.run_preempted(&alg, &input, &[1e-9, 2e-9, 3e-9]);
    assert_eq!(res.preemptions, 3);
    let blocks: Vec<((usize, usize), DenseMatrix)> = res
        .output
        .into_iter()
        .map(|p| {
            let m = match p.value {
                DenseBlock::C(m) => (*m).clone(),
                _ => panic!("non-C output"),
            };
            ((p.key.i as usize, p.key.j as usize), m)
        })
        .collect();
    assert_eq!(grid.assemble(&blocks), want);
}

#[test]
fn works_on_minimum_geometry() {
    // 1×1 blocks, q = side: stress the index arithmetic.
    let side = 6;
    let mut rng = Xoshiro256ss::new(17);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let want = a.matmul_naive(&b);
    for rho in [1usize, 2, 3, 6] {
        let (got, _) = multiply_dense_3d(
            &a,
            &b,
            &cfg(1, rho, PartitionerKind::Balanced),
            Arc::new(NaiveMultiply),
        )
        .unwrap();
        assert_eq!(got, want, "rho={rho}");
    }
}

#[test]
fn single_block_degenerate_case() {
    // block = side: q = 1, one product, two rounds (1 product + 1 sum).
    let side = 16;
    let mut rng = Xoshiro256ss::new(18);
    let a = gen::dense_int(side, side, &mut rng);
    let b = gen::dense_int(side, side, &mut rng);
    let (got, metrics) = multiply_dense_3d(
        &a,
        &b,
        &cfg(side, 1, PartitionerKind::Balanced),
        Arc::new(NaiveMultiply),
    )
    .unwrap();
    assert_eq!(got, a.matmul_naive(&b));
    assert_eq!(metrics.num_rounds(), 2);
}
