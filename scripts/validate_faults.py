#!/usr/bin/env python3
"""Structural validator for m3's fault-injection counter output.

Usage: validate_faults.py OUTPUT.txt [OUTPUT.txt ...]

Validates the ``FAULTS`` lines printed by ``m3 chaos`` and
``m3 serve --faults`` (stdlib only, no third-party deps). Per file:

  1. at least one ``FAULTS attempts=...`` counter line is present;
  2. the attempt ledger balances: every attempt either committed,
     failed, or was cancelled by a winning speculative rival
     (``attempts == successes + failures + spec_cancelled``);
  3. every retry follows a failure (``retries <= failures``), every
     re-execution is a failure of a killed-node attempt
     (``reexecuted <= failures``), and no speculative attempt is
     cancelled without having been launched
     (``spec_cancelled <= spec_launched``);
  4. round recovery accounting is sane on every
     ``FAULTS rounds ...`` line: ``recovered <= executed`` and
     ``fallbacks <= recovered`` (a whole-round fallback is only ever
     booked for a round that needed recovery);
  5. no ``verify=FAIL`` marker appears anywhere in the output.

Exits non-zero with a diagnostic on the first violation.
"""

import re
import sys

COUNTER_LINE = re.compile(
    r"^FAULTS attempts=(\d+) successes=(\d+) failures=(\d+) retries=(\d+) "
    r"reexecuted=(\d+) spec_launched=(\d+) spec_cancelled=(\d+)\s*$"
)
ROUNDS_LINE = re.compile(
    r"^FAULTS rounds executed=(\d+) recovered=(\d+) fallbacks=(\d+)\s*$"
)


def fail(msg):
    print(f"validate_faults: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_counters(path, lineno, m):
    attempts, successes, failures, retries, reexecuted, launched, cancelled = (
        int(g) for g in m.groups()
    )
    if attempts != successes + failures + cancelled:
        fail(
            f"{path}:{lineno}: attempt ledger out of balance: "
            f"{attempts} != {successes} + {failures} + {cancelled}"
        )
    if retries > failures:
        fail(f"{path}:{lineno}: retries={retries} > failures={failures}")
    if reexecuted > failures:
        fail(f"{path}:{lineno}: reexecuted={reexecuted} > failures={failures}")
    if cancelled > launched:
        fail(
            f"{path}:{lineno}: spec_cancelled={cancelled} > "
            f"spec_launched={launched}"
        )


def check_rounds(path, lineno, m):
    executed, recovered, fallbacks = (int(g) for g in m.groups())
    if recovered > executed:
        fail(f"{path}:{lineno}: recovered={recovered} > executed={executed}")
    if fallbacks > recovered:
        fail(f"{path}:{lineno}: fallbacks={fallbacks} > recovered={recovered}")


def check_file(path):
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.readlines()
    except OSError as e:
        fail(f"{path}: cannot read: {e}")
    counters = rounds = 0
    for lineno, line in enumerate(lines, start=1):
        if "verify=FAIL" in line:
            fail(f"{path}:{lineno}: verification failure reported")
        m = COUNTER_LINE.match(line)
        if m:
            check_counters(path, lineno, m)
            counters += 1
            continue
        m = ROUNDS_LINE.match(line)
        if m:
            check_rounds(path, lineno, m)
            rounds += 1
    if counters == 0:
        fail(f"{path}: no 'FAULTS attempts=...' counter line found")
    return counters, rounds


def main(argv):
    if len(argv) < 2:
        fail("usage: validate_faults.py OUTPUT.txt [OUTPUT.txt ...]")
    total_counters = total_rounds = 0
    for path in argv[1:]:
        counters, rounds = check_file(path)
        total_counters += counters
        total_rounds += rounds
    print(
        f"validate_faults: OK: {len(argv) - 1} file(s), "
        f"{total_counters} counter line(s), {total_rounds} rounds line(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
