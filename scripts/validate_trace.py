#!/usr/bin/env python3
"""Structural validator for m3's exported Chrome trace_event JSON.

Usage: validate_trace.py TRACE.json [REPORT.txt]

Checks (stdlib only, no third-party deps):

  1. the file parses as JSON with a non-empty ``traceEvents`` list;
  2. every event is a complete span (``ph == "X"``), an instant
     (``ph == "i"``) or metadata (``ph == "M"``); spans carry numeric
     ``ts >= 0`` / ``dur >= 0`` plus ``pid``/``tid``/``name``;
  3. every phase span (map/shuffle/reduce/commit) temporally nests
     inside a round span of the same job process and round index;
  4. per round span, the contained phase durations sum to at most the
     round's duration (plus a float-formatting epsilon);
  5. instants are scheduler decisions: ``s == "p"`` and args carrying
     ``run``/``job``/``round``/``virt_secs``;
  6. optionally, the textual report's ``TRACE round …`` lines
     cross-check against the round spans: same (job, round) multiset,
     walls matching within the µs-formatting tolerance.

Exits non-zero with a diagnostic on the first violation.
"""

import json
import re
import sys

PHASE_NAMES = {"map", "shuffle", "reduce", "commit"}
# Exported ts/dur are microseconds printed with three decimals
# (nanosecond precision); allow one-ULP slack on comparisons.
EPS_US = 0.01

TRACE_LINE = re.compile(
    r"^TRACE round job=(\d+) r=(\d+) wall_ns=(\d+) map_ns=(\d+) "
    r"shuffle_ns=(\d+) reduce_ns=(\d+) commit_ns=(\d+)$"
)


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load_events(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: cannot parse: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    return events


def classify(events):
    spans, instants, metas = [], [], []
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph == "X":
            for key in ("ts", "dur"):
                v = e.get(key)
                if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                    fail(f"event #{i}: span has bad {key}: {v!r}")
            for key in ("pid", "tid", "name"):
                if key not in e:
                    fail(f"event #{i}: span missing {key}")
            spans.append(e)
        elif ph == "i":
            if e.get("s") != "p":
                fail(f"event #{i}: instant missing process scope s=p")
            args = e.get("args", {})
            for key in ("run", "job", "round", "virt_secs"):
                if key not in args:
                    fail(f"event #{i}: instant args missing {key}")
            instants.append(e)
        elif ph == "M":
            metas.append(e)
        else:
            fail(f"event #{i}: unexpected ph {ph!r}")
    return spans, instants, metas


def arg(e, key):
    return e.get("args", {}).get(key)


def contains(outer, inner):
    return (
        outer["ts"] - EPS_US <= inner["ts"]
        and inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + EPS_US
    )


def check_nesting(spans):
    rounds = [e for e in spans if e["name"] == "round"]
    phases = [e for e in spans if e["name"] in PHASE_NAMES]
    for p in phases:
        owners = [
            r
            for r in rounds
            if r["pid"] == p["pid"]
            and arg(r, "job") == arg(p, "job")
            and arg(r, "round") == arg(p, "round")
            and contains(r, p)
        ]
        if not owners:
            fail(
                f"phase span {p['name']} (job={arg(p, 'job')} "
                f"round={arg(p, 'round')} ts={p['ts']}) nests in no round span"
            )
    for r in rounds:
        total = sum(
            p["dur"]
            for p in phases
            if p["pid"] == r["pid"]
            and arg(p, "job") == arg(r, "job")
            and arg(p, "round") == arg(r, "round")
            and contains(r, p)
        )
        if total > r["dur"] + EPS_US * max(1, len(phases)):
            fail(
                f"round span job={arg(r, 'job')} round={arg(r, 'round')}: "
                f"phase durations sum to {total} > round dur {r['dur']}"
            )
    return rounds, phases


def check_report(report_path, rounds):
    with open(report_path, encoding="utf-8") as f:
        lines = [m for m in (TRACE_LINE.match(l) for l in f) if m]
    if not lines:
        fail(f"{report_path}: no 'TRACE round' lines found")
    if len(lines) != len(rounds):
        fail(
            f"{report_path}: {len(lines)} TRACE lines but "
            f"{len(rounds)} round spans in the JSON"
        )
    unmatched = list(rounds)
    for m in lines:
        job, rnd, wall_ns = int(m.group(1)), int(m.group(2)), int(m.group(3))
        hit = None
        for i, r in enumerate(unmatched):
            if (
                arg(r, "job") == job
                and arg(r, "round") == rnd
                and abs(r["dur"] * 1000.0 - wall_ns) <= 2.0
            ):
                hit = i
                break
        if hit is None:
            fail(
                f"{report_path}: TRACE line job={job} r={rnd} "
                f"wall_ns={wall_ns} matches no exported round span"
            )
        unmatched.pop(hit)
    return len(lines)


def main(argv):
    if len(argv) < 2:
        fail("usage: validate_trace.py TRACE.json [REPORT.txt]")
    events = load_events(argv[1])
    spans, instants, metas = classify(events)
    if not spans:
        fail("no complete ('X') spans in the trace")
    if not any(e["name"] == "round" for e in spans):
        fail("no round spans in the trace")
    rounds, phases = check_nesting(spans)
    if not phases:
        fail("round spans present but no phase spans nest inside them")
    checked = 0
    if len(argv) > 2:
        checked = check_report(argv[2], rounds)
    print(
        f"validate_trace: OK: {len(spans)} spans ({len(rounds)} rounds, "
        f"{len(phases)} phases), {len(instants)} scheduler instants, "
        f"{len(metas)} metadata records"
        + (f"; {checked} report TRACE lines cross-checked" if checked else "")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
