#!/usr/bin/env python3
"""Structural validator for m3's dumped shuffle wire frames.

Usage: validate_wire.py FRAMES.bin [FRAMES.bin ...]

``m3 multiply --dump-wire PATH`` writes the round-0 map-output frames
exactly as the serialized transport puts them on the wire: one
self-delimiting ``M3WF`` frame per sender, concatenated. This script
re-walks that byte stream from outside Rust with nothing but the
stdlib, checking the format is honest about its own framing:

  1. every frame starts with magic ``M3WF``, version 1, and a known
     kind (1 = key/value pair batch);
  2. the ``body_len`` header delimits the frame exactly — walking
     pair-by-pair consumes the body to the last byte;
  3. each pair is ``key_len u32 | key | value_len u32 | value`` with
     non-zero lengths that stay inside the body;
  4. the concatenation is exact: the final frame ends on the final
     byte of the file, and at least one frame carrying at least one
     pair was present.

Exits non-zero with a diagnostic on the first violation; on success
prints a per-file frame/pair/byte summary.
"""

import struct
import sys

MAGIC = b"M3WF"
VERSION = 1
KIND_PAIRS = 1
HEADER_LEN = 10  # magic(4) + version(1) + kind(1) + body_len(4)


def fail(msg):
    print(f"validate_wire: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def u32(buf, off, what):
    if off + 4 > len(buf):
        fail(f"truncated {what} at offset {off}")
    return struct.unpack_from("<I", buf, off)[0], off + 4


def walk_frame(buf, off, index):
    """Validate one frame starting at ``off``; return (pairs, next_off)."""
    if off + HEADER_LEN > len(buf):
        fail(f"frame {index}: truncated header at offset {off}")
    if buf[off : off + 4] != MAGIC:
        fail(f"frame {index}: bad magic {buf[off:off + 4]!r} at offset {off}")
    version = buf[off + 4]
    if version != VERSION:
        fail(f"frame {index}: unknown version {version}")
    kind = buf[off + 5]
    if kind != KIND_PAIRS:
        fail(f"frame {index}: unknown kind {kind}")
    body_len = struct.unpack_from("<I", buf, off + 6)[0]
    body_end = off + HEADER_LEN + body_len
    if body_end > len(buf):
        fail(f"frame {index}: body_len {body_len} overruns the file")

    pos = off + HEADER_LEN
    pair_count, pos = u32(buf, pos, f"frame {index} pair count")
    for p in range(pair_count):
        key_len, pos = u32(buf, pos, f"frame {index} pair {p} key length")
        if key_len == 0:
            fail(f"frame {index} pair {p}: zero-length key")
        if pos + key_len > body_end:
            fail(f"frame {index} pair {p}: key overruns the body")
        pos += key_len
        value_len, pos = u32(buf, pos, f"frame {index} pair {p} value length")
        if value_len == 0:
            fail(f"frame {index} pair {p}: zero-length value")
        if pos + value_len > body_end:
            fail(f"frame {index} pair {p}: value overruns the body")
        pos += value_len
    if pos != body_end:
        fail(
            f"frame {index}: body_len {body_len} does not delimit its "
            f"pairs (walked to {pos - off - HEADER_LEN})"
        )
    return pair_count, body_end


def validate(path):
    with open(path, "rb") as f:
        buf = f.read()
    if not buf:
        fail(f"{path}: empty dump")
    off = 0
    frames = 0
    pairs = 0
    while off < len(buf):
        n, off = walk_frame(buf, off, frames)
        frames += 1
        pairs += n
    if off != len(buf):
        fail(f"{path}: {len(buf) - off} trailing byte(s) after the last frame")
    if pairs == 0:
        fail(f"{path}: no pairs in any frame")
    print(f"validate_wire: OK: {path}: {frames} frame(s), {pairs} pair(s), {len(buf)} bytes")


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    for path in argv[1:]:
        validate(path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
